"""TPU v5e hardware constants used by the roofline cost model, the latency
predictor's ground-truth simulator, and the dry-run roofline analysis.

The container is CPU-only; TPU v5e is the *target*. All perf reasoning in this
repo (costmodel, roofline, predictor fits) is derived from these constants.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bytes: float            # HBM capacity per chip
    hbm_bw: float               # bytes/s HBM bandwidth per chip
    ici_bw_per_link: float      # bytes/s per ICI link
    ici_links: int              # links per chip in a 2D torus
    host_dma_bw: float          # bytes/s host<->HBM (weight-window swapping)
    vmem_bytes: float           # VMEM per core (Pallas tiling budget)
    mxu_tile: int               # MXU systolic dimension (128x128)


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bytes=16 * 1024**3,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=4,
    host_dma_bw=32e9,
    vmem_bytes=128 * 1024**2,
    mxu_tile=128,
)

# The paper evaluates on Ada6000/A100; kept for the paper-figure benchmarks that
# reason about the GPU baseline (Fig. 1/4 reproduction uses the same roofline
# methodology with these constants to show the shape of the curves).
ADA6000 = ChipSpec(
    name="ada6000",
    peak_flops_bf16=182.5e12,
    hbm_bytes=48 * 1024**3,
    hbm_bw=960e9,
    ici_bw_per_link=0.0,
    ici_links=0,
    host_dma_bw=32e9,
    vmem_bytes=0.0,
    mxu_tile=16,
)

DEFAULT_CHIP = TPU_V5E

# Mesh shapes for the production dry-run (see launch/mesh.py).
SINGLE_POD_SHAPE = (16, 16)            # ("data", "model") = 256 chips
MULTI_POD_SHAPE = (2, 16, 16)          # ("pod", "data", "model") = 512 chips
