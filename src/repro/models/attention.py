"""Attention layers: GQA/MHA (+ qk_norm, SWA/local windows) and MLA.

Two execution paths per layer:
  * prefill/train: chunked flash attention over the whole sequence
  * decode: one-token attention against a KV cache (dense jnp fallback here;
    the Pallas kernel in kernels/decode_attention is swapped in by ops.py)

Caches use per-request absolute positions so continuous batching works:
  full cache : k/v (B, S_max, KV, hd), kv_pos (B, S_max) int32 (-1 = empty)
  SWA cache  : same but S_max = window, ring-buffer indexed by pos % window
  MLA cache  : c_kv (B, S_max, kv_rank), k_rope (B, S_max, rope_dim), kv_pos
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = Dict[str, jax.Array]


# ------------------------------------------------------------------ init ---
def attn_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, KV * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, KV * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H * hd, d)) * (H * hd) ** -0.5).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def mla_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    qr, kr = cfg.mla_q_rank, cfg.mla_kv_rank
    nd, rd, vd = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        "wq_a": (jax.random.normal(ks[0], (d, qr)) * s).astype(dtype),
        "q_norm": jnp.ones((qr,), dtype),
        "wq_b": (jax.random.normal(ks[1], (qr, H * (nd + rd))) * qr ** -0.5).astype(dtype),
        "wkv_a": (jax.random.normal(ks[2], (d, kr + rd)) * s).astype(dtype),
        "kv_norm": jnp.ones((kr,), dtype),
        "wkv_b": (jax.random.normal(ks[3], (kr, H * (nd + vd))) * kr ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[4], (H * vd, d)) * (H * vd) ** -0.5).astype(dtype),
    }


def make_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16,
               window: int = 0, quantized: bool = False
               ) -> Dict[str, jax.Array]:
    """Empty per-layer cache (without the leading layer axis).

    quantized=True stores K/V as int8 with per-token f32 scales (beyond-
    paper: halves the decode memory term; scales fold into the softmax
    weights at read time — see decode_attn_ref). MLA latent caches stay
    bf16 (already 8x smaller than MHA)."""
    eff = min(s_max, window) if window else s_max
    if cfg.mla:
        return {
            "c_kv": jnp.zeros((batch, eff, cfg.mla_kv_rank), dtype),
            "k_rope": jnp.zeros((batch, eff, cfg.mla_rope_dim), dtype),
            "kv_pos": jnp.full((batch, eff), -1, jnp.int32),
        }
    kv_dt = jnp.int8 if quantized else dtype
    c = {
        "k": jnp.zeros((batch, eff, cfg.num_kv_heads, cfg.head_dim), kv_dt),
        "v": jnp.zeros((batch, eff, cfg.num_kv_heads, cfg.head_dim), kv_dt),
        "kv_pos": jnp.full((batch, eff), -1, jnp.int32),
    }
    if quantized:
        c["k_scale"] = jnp.zeros((batch, eff), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, eff), jnp.float32)
    return c


def _quantize_tok(x):
    """Per-token symmetric int8: x (B, S, KV, hd) -> (q, scale (B, S))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(2, 3))
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32)
                           / scale[:, :, None, None]), -127, 127)
    return q.astype(jnp.int8), scale


# ------------------------------------------------------------- GQA paths ---
def _project_qkv(p: Params, x, cfg: ModelConfig, lora, lora_scale):
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def proj(w, name, n_out):
        y = jnp.einsum("bsd,do->bso", x, w.astype(x.dtype))
        if lora is not None and name in lora:
            a, b = lora[name]
            y = y + lora_scale * jnp.einsum(
                "bsr,ro->bso", jnp.einsum("bsd,dr->bsr", x, a.astype(x.dtype)),
                b.astype(x.dtype))
        return y.reshape(B, S, n_out, hd)

    q = proj(p["wq"], "q", H)
    k = proj(p["wk"], "k", KV)
    v = proj(p["wv"], "v", KV)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _out_proj(p: Params, o, cfg: ModelConfig, lora, lora_scale):
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    y = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(o.dtype))
    if lora is not None and "o" in lora:
        a, b = lora["o"]
        y = y + lora_scale * jnp.einsum(
            "bsr,rd->bsd", jnp.einsum("bsh,hr->bsr", o, a.astype(o.dtype)),
            b.astype(o.dtype))
    return constrain(y, ("batch", "seq_sp", None))


def attn_prefill(p: Params, x, positions, cfg: ModelConfig, *,
                 window: int = 0, cache: Optional[Dict] = None,
                 lora=None, lora_scale: float = 0.0):
    """Full-sequence attention. positions: (B, S) absolute. Returns (out, cache)."""
    q, k, v = _project_qkv(p, x, cfg, lora, lora_scale)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    o = L.flash_attention(q, k, v, causal=True, window=window,
                          q_offset=positions[:, 0])
    out = _out_proj(p, o, cfg, lora, lora_scale)
    new_cache = None
    if cache is not None:
        # match the cache's (seq-sharded, heads-replicated) layout BEFORE
        # the write — otherwise GSPMD falls back to full rematerialization
        # of the cache write (observed as an involuntary-remat warning)
        kw = constrain(k, ("batch", "seq_sp", None, None))
        vw = constrain(v, ("batch", "seq_sp", None, None))
        new_cache = _cache_write_prefill(cache, kw, vw, positions, window)
    return out, new_cache


def _cache_write_bulk(cache, k, v, positions, window):
    """Write a token chunk into the cache (ring-buffered when windowed)."""
    S_max = cache["k"].shape[1]
    slots = positions % S_max if window else positions
    B = k.shape[0]
    bidx = jnp.arange(B)[:, None]
    out = dict(cache)
    if "k_scale" in cache:                       # int8 KV
        kq, ks = _quantize_tok(k)
        vq, vs = _quantize_tok(v)
        out["k"] = cache["k"].at[bidx, slots].set(kq)
        out["v"] = cache["v"].at[bidx, slots].set(vq)
        out["k_scale"] = cache["k_scale"].at[bidx, slots].set(ks)
        out["v_scale"] = cache["v_scale"].at[bidx, slots].set(vs)
    else:
        out["k"] = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype))
    out["kv_pos"] = cache["kv_pos"].at[bidx, slots].set(positions)
    return out


def _cache_write_prefill(cache, k, v, positions, window):
    """Contiguous prefill cache write via dynamic_update_slice.

    Prefill positions are arange-contiguous per request (prompt processing),
    so the write is a slice update — a batched scatter here makes GSPMD
    all-gather the ENTIRE seq-sharded cache per layer (observed: 2 x 16GiB
    f32 all-gathers per layer in the 32k-prefill dry-run).
    Ring-buffered (SWA) caches keep only the last `W` tokens: two slice
    updates split at the (static) wrap point."""
    S_max = cache["k"].shape[1]
    B, S = k.shape[:2]
    kd, vd = cache["k"].dtype, cache["v"].dtype

    def dus(buf, upd, start):
        return jax.lax.dynamic_update_slice_in_dim(buf, upd, start, axis=1)

    quant = "k_scale" in cache
    if quant:
        k, k_sc = _quantize_tok(k)
        v, v_sc = _quantize_tok(v)
        kd = vd = jnp.int8

    if not window:
        out = dict(cache)
        out["k"] = dus(cache["k"], k[:, :S_max].astype(kd), 0)
        out["v"] = dus(cache["v"], v[:, :S_max].astype(vd), 0)
        out["kv_pos"] = dus(cache["kv_pos"], positions[:, :S_max], 0)
        if quant:
            out["k_scale"] = dus(cache["k_scale"], k_sc[:, :S_max], 0)
            out["v_scale"] = dus(cache["v_scale"], v_sc[:, :S_max], 0)
        return out
    # ring buffer: last W tokens; token p lives in slot p % W
    W = S_max
    if S <= W:
        return _cache_write_bulk(cache, k, v, positions, window) if not quant \
            else _ring_quant_fallback(cache, k, k_sc, v, v_sc, positions,
                                      window)
    kt, vt, pt = k[:, -W:], v[:, -W:], positions[:, -W:]
    split = S % W               # static wrap point
    first = W - split

    def write(buf, t):
        buf = dus(buf, t[:, :first].astype(buf.dtype), split)
        if split:
            buf = dus(buf, t[:, first:].astype(buf.dtype), 0)
        return buf

    out = dict(cache)
    out["k"] = write(cache["k"], kt)
    out["v"] = write(cache["v"], vt)
    out["kv_pos"] = write(cache["kv_pos"], pt)
    if quant:
        out["k_scale"] = write(cache["k_scale"], k_sc[:, -W:])
        out["v_scale"] = write(cache["v_scale"], v_sc[:, -W:])
    return out


def _ring_quant_fallback(cache, kq, k_sc, vq, v_sc, positions, window):
    S_max = cache["k"].shape[1]
    slots = positions % S_max
    bidx = jnp.arange(kq.shape[0])[:, None]
    out = dict(cache)
    out["k"] = cache["k"].at[bidx, slots].set(kq)
    out["v"] = cache["v"].at[bidx, slots].set(vq)
    out["k_scale"] = cache["k_scale"].at[bidx, slots].set(k_sc)
    out["v_scale"] = cache["v_scale"].at[bidx, slots].set(v_sc)
    out["kv_pos"] = cache["kv_pos"].at[bidx, slots].set(positions)
    return out


def attn_decode(p: Params, x, positions, cache: Dict, cfg: ModelConfig, *,
                window: int = 0, lora=None, lora_scale: float = 0.0,
                decode_attn_fn: Optional[Callable] = None):
    """One-token decode. x: (B, 1, d); positions: (B,). Returns (out, cache).

    Sharding note: the KV cache is SEQUENCE-sharded on the model axis (SPMD
    flash-decode). q/k/v for the new token are tiny, so they are kept
    replicated on the model axis — scores then inherit the cache's seq
    sharding and the softmax/combine reduce with small all-reduces instead
    of gathering the (huge) cache."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, lora, lora_scale)
    q = constrain(q, ("batch", None, None, None))
    k = constrain(k, ("batch", None, None, None))
    v = constrain(v, ("batch", None, None, None))
    q = L.apply_rope(q, positions[:, None], cfg.rope_theta)
    k = L.apply_rope(k, positions[:, None], cfg.rope_theta)
    cache = _cache_write_bulk(cache, k, v, positions[:, None], window)
    kc, vc, kv_pos = cache["k"], cache["v"], cache["kv_pos"]
    if decode_attn_fn is None or "k_scale" in cache:
        decode_attn_fn = decode_attn_ref
    o = decode_attn_fn(q[:, 0], kc, vc, kv_pos, positions, window,
                       scales=(cache.get("k_scale"), cache.get("v_scale")))
    out = _out_proj(p, o[:, None], cfg, lora, lora_scale)
    return out, cache


def decode_attn_ref(q, kc, vc, kv_pos, positions, window: int = 0,
                    scale: Optional[float] = None, scales=None):
    """Dense decode attention oracle. q: (B, H, hd); cache (B, S, KV, hd).

    int8 caches (scales=(k_scale, v_scale), per-token f32): the dequant
    scales fold into the scores / softmax weights — the cache itself is
    never dequantized to a wide buffer."""
    B, H, hd = q.shape
    KV = kc.shape[2]
    g = H // KV
    scale = scale if scale is not None else hd ** -0.5
    quant = scales is not None and scales[0] is not None
    out_dtype = q.dtype if quant else vc.dtype
    qr = q.reshape(B, KV, g, hd)
    if quant:
        s = jnp.einsum("bkgh,bskh->bkgs", qr.astype(jnp.bfloat16),
                       kc.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        s = s * scales[0][:, None, None, :] * scale
    else:
        s = jnp.einsum("bkgh,bskh->bkgs", qr, kc,
                       preferred_element_type=jnp.float32) * scale
    valid = (kv_pos >= 0) & (kv_pos <= positions[:, None])
    if window > 0:
        valid &= kv_pos > positions[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    pmax = jnp.where(jnp.isneginf(pmax), 0.0, pmax)
    e = jnp.exp(s - pmax)
    e = jnp.where(valid[:, None, None, :], e, 0.0)
    if quant:
        ew = e * scales[1][:, None, None, :]          # fold v dequant scale
        o = jnp.einsum("bkgs,bskh->bkgh", ew.astype(jnp.bfloat16),
                       vc.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    else:
        # cast the (small) softmax weights down to the cache dtype instead
        # of the cache up to f32 — XLA hoists a loop-invariant cache->f32
        # convert out of the layer scan otherwise (full extra cache copy)
        o = jnp.einsum("bkgs,bskh->bkgh", e.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
    o = o / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)[..., 0][..., None]
    return o.reshape(B, H, hd).astype(out_dtype)


# -------------------------------------------------------------- MLA paths ---
def mla_prefill(p: Params, x, positions, cfg: ModelConfig, *,
                cache: Optional[Dict] = None, lora=None, lora_scale: float = 0.0):
    B, S, d = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kr = cfg.mla_kv_rank

    cq = L.rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)),
                    p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,ro->bso", cq, p["wq_b"].astype(x.dtype))
    if lora is not None and "q" in lora:
        a, b = lora["q"]
        q = q + lora_scale * jnp.einsum(
            "bsr,ro->bso", jnp.einsum("bsc,cr->bsr", cq, a.astype(x.dtype)),
            b.astype(x.dtype))
    q = q.reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv, k_rope = ckv[..., :kr], ckv[..., kr:]
    c_kv = L.rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    kv = jnp.einsum("bsr,ro->bso", c_kv, p["wkv_b"].astype(x.dtype))
    kv = kv.reshape(B, S, H, nd + vd)
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rd))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_full = constrain(q_full, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    scale = (nd + rd) ** -0.5
    o = L.flash_attention(q_full, k, v, causal=True, scale=scale,
                          q_offset=positions[:, 0])
    o = o.reshape(B, S, H * vd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(x.dtype))
    if lora is not None and "o" in lora:
        a, b = lora["o"]
        out = out + lora_scale * jnp.einsum(
            "bsr,rd->bsd", jnp.einsum("bsh,hr->bsr", o, a.astype(x.dtype)),
            b.astype(x.dtype))
    out = constrain(out, ("batch", "seq_sp", None))
    new_cache = None
    if cache is not None:
        # contiguous prefill write (see _cache_write_prefill)
        S_max = cache["c_kv"].shape[1]
        c_kv_w = constrain(c_kv, ("batch", "seq_sp", None))

        def dus(buf, upd):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, upd[:, :S_max].astype(buf.dtype), 0, axis=1)

        new_cache = {
            "c_kv": dus(cache["c_kv"], c_kv_w),
            "k_rope": dus(cache["k_rope"], k_rope),
            "kv_pos": dus(cache["kv_pos"], positions),
        }
    return out, new_cache


def mla_decode(p: Params, x, positions, cache: Dict, cfg: ModelConfig, *,
               lora=None, lora_scale: float = 0.0):
    """Absorbed-matmul MLA decode: attention runs in the latent space, so the
    cache stays (kv_rank + rope_dim) per token — the paper-relevant memory win."""
    B = x.shape[0]
    H = cfg.num_heads
    nd, rd, vd = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kr = cfg.mla_kv_rank

    cq = L.rms_norm(jnp.einsum("bd,dr->br", x[:, 0], p["wq_a"].astype(x.dtype)),
                    p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("br,ro->bo", cq, p["wq_b"].astype(x.dtype))
    if lora is not None and "q" in lora:
        a, b = lora["q"]
        q = q + lora_scale * (cq @ a.astype(x.dtype)) @ b.astype(x.dtype)
    # two-step resharding: first materialize q col-sharded (the natural dot
    # output), THEN replicate. A direct replicate-constraint makes GSPMD
    # all-gather the (q_rank x H*(nd+rd)) WEIGHT — 576MB vs 6MB per layer.
    q = constrain(q, ("batch", "ff"))
    # keep the one-token q replicated on the model axis: the latent cache is
    # sequence-sharded and scores must inherit THAT sharding (flash-decode)
    q = constrain(q, ("batch", None))
    q = q.reshape(B, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = L.apply_rope(q_rope[:, None], positions[:, None],
                          cfg.rope_theta)[:, 0]

    ckv = jnp.einsum("bd,dr->br", x[:, 0], p["wkv_a"].astype(x.dtype))
    c_kv, k_rope = ckv[..., :kr], ckv[..., kr:]
    c_kv = L.rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, None, None, :], positions[:, None],
                          cfg.rope_theta)[:, 0, 0]

    bidx = jnp.arange(B)
    cache = {
        "c_kv": cache["c_kv"].at[bidx, positions].set(c_kv.astype(cache["c_kv"].dtype)),
        "k_rope": cache["k_rope"].at[bidx, positions].set(
            k_rope.astype(cache["k_rope"].dtype)),
        "kv_pos": cache["kv_pos"].at[bidx, positions].set(positions),
    }

    # Absorb W_kv_b's key half into q: q_lat (B, H, kv_rank)
    wkv_b = p["wkv_b"].reshape(kr, H, nd + vd).astype(x.dtype)
    w_k = wkv_b[..., :nd]                                   # (kr, H, nd)
    w_v = wkv_b[..., nd:]                                   # (kr, H, vd)
    q_lat = constrain(jnp.einsum("bhn,rhn->bhr", q_nope, w_k),
                      ("batch", None, None))
    scale = (nd + rd) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, cache["c_kv"],
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,bsr->bhs", q_rope, cache["k_rope"],
                      preferred_element_type=jnp.float32)) * scale
    valid = (cache["kv_pos"] >= 0) & (cache["kv_pos"] <= positions[:, None])
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    pmax = jnp.where(jnp.isneginf(pmax), 0.0, pmax)
    e = jnp.exp(s - pmax)
    e = jnp.where(valid[:, None, :], e, 0.0)
    o_lat = jnp.einsum("bhs,bsr->bhr", e.astype(cache["c_kv"].dtype),
                       cache["c_kv"], preferred_element_type=jnp.float32)
    o_lat = o_lat / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhr,rhv->bhv", o_lat.astype(x.dtype), w_v)
    o = o.reshape(B, H * vd)
    out = o @ p["wo"].astype(x.dtype)
    if lora is not None and "o" in lora:
        a, b = lora["o"]
        out = out + lora_scale * (o @ a.astype(x.dtype)) @ b.astype(x.dtype)
    return out[:, None], cache
