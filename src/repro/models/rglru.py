"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block layout (Griffin "recurrent block"):
    x -> branch A: linear(d->w) -> GeLU
      -> branch B: linear(d->w) -> causal conv1d(width 4) -> RG-LRU
    out = (A * B_rglru) @ out_proj

RG-LRU (per channel, diagonal recurrence):
    r_t = sigmoid(block_diag_linear_a(x_t))        recurrence gate
    i_t = sigmoid(block_diag_linear_x(x_t))        input gate
    a_t = exp(c * softplus(Lambda) * (-r_t))       in (0,1), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Prefill/train uses an associative scan (parallel on TPU); decode is O(1).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

_C = 8.0
_NUM_BLOCKS = 16


def rglru_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    nb = _NUM_BLOCKS if w % _NUM_BLOCKS == 0 else 1
    bs = w // nb
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_y": (jax.random.normal(ks[0], (d, w)) * s).astype(dtype),
        "in_x": (jax.random.normal(ks[1], (d, w)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (4, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": (jax.random.normal(ks[3], (nb, bs, bs)) * bs ** -0.5
                   ).astype(dtype),
        "gate_x": (jax.random.normal(ks[4], (nb, bs, bs)) * bs ** -0.5
                   ).astype(dtype),
        "lamb": jnp.linspace(-4.0, 4.0, w).astype(jnp.float32),   # Lambda param
        "out_proj": (jax.random.normal(ks[5], (w, d)) * w ** -0.5).astype(dtype),
    }


def make_rglru_state(cfg: ModelConfig, batch: int) -> Dict:
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 3, w), jnp.bfloat16),
    }


def _block_diag(x, w):
    """x: (..., W) with W = nb*bs; w: (nb, bs, bs)."""
    nb, bs, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, bs)
    y = jnp.einsum("...nb,nbc->...nc", xs, w.astype(x.dtype))
    return y.reshape(*x.shape[:-1], nb * bs)


def _gates(p, xb):
    r = jax.nn.sigmoid(_block_diag(xb, p["gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(xb, p["gate_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lamb"]) * r           # (..., w), <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * xb.astype(jnp.float32)


def rglru_forward(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                  state: Optional[Dict] = None
                  ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d) -> (out, new_state)."""
    B, S, d = x.shape
    y_branch = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["in_y"].astype(x.dtype))
        .astype(jnp.float32)).astype(x.dtype)
    xb = jnp.einsum("bsd,dw->bsw", x, p["in_x"].astype(x.dtype))

    # causal conv1d width 4
    pad = (jnp.zeros((B, 3, xb.shape[-1]), xb.dtype) if state is None
           else state["conv"].astype(xb.dtype))
    xp = jnp.concatenate([pad, xb], axis=1)
    conv = sum(xp[:, i:i + S] * p["conv_w"][i].astype(xb.dtype)
               for i in range(4)) + p["conv_b"].astype(xb.dtype)

    a, bx = _gates(p, conv)                                # (B,S,w) f32

    # h_t = a_t h_{t-1} + bx_t  via associative scan
    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    if state is not None:
        # fold h0 in as a virtual step 0
        a0 = jnp.ones((B, 1, a.shape[-1]), jnp.float32)
        a_ = jnp.concatenate([a0, a], axis=1)
        b_ = jnp.concatenate([state["h"][:, None], bx], axis=1)
        aa, hh = jax.lax.associative_scan(comb, (a_, b_), axis=1)
        h = hh[:, 1:]
    else:
        aa, h = jax.lax.associative_scan(comb, (a, bx), axis=1)

    out = jnp.einsum("bsw,wd->bsd",
                     (y_branch.astype(jnp.float32) * h).astype(x.dtype),
                     p["out_proj"].astype(x.dtype))
    new_state = None
    if state is not None:
        new_state = {"h": h[:, -1], "conv": xp[:, S:].astype(jnp.bfloat16)}
    return out, new_state


def rglru_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, d). O(1) recurrent update."""
    B = x.shape[0]
    y_branch = jax.nn.gelu(
        (x[:, 0] @ p["in_y"].astype(x.dtype)).astype(jnp.float32)
    ).astype(x.dtype)
    xb = x[:, 0] @ p["in_x"].astype(x.dtype)
    buf = jnp.concatenate([state["conv"].astype(xb.dtype), xb[:, None]], axis=1)
    conv = jnp.einsum("bwc,wc->bc", buf, p["conv_w"].astype(xb.dtype))
    conv = conv + p["conv_b"].astype(xb.dtype)
    a, bx = _gates(p, conv)
    h = a * state["h"] + bx
    out = ((y_branch.astype(jnp.float32) * h).astype(x.dtype)
           @ p["out_proj"].astype(x.dtype))
    return out[:, None], {"h": h, "conv": buf[:, 1:].astype(jnp.bfloat16)}
