"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / multimodal-stub
LMs; family-specific fields are ignored by families that don't use them.
Configs are pure data — model code lives in `models/model.py` and friends.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    # Projections that receive adapters. SSM blocks map these onto their
    # in/out projections; MoE layers adapt attention + shared expert only
    # (routed experts stay frozen — standard practice, keeps adapters tiny).
    targets: Tuple[str, ...] = ("q", "k", "v", "o", "gate", "up", "down")
    dropout: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads

    # --- attention variants ---
    attn_type: str = "full"       # full | swa
    window: int = 0               # SWA window (attn_type == "swa")
    qk_norm: bool = False         # qwen3-style per-head RMSNorm on q/k
    rope_theta: float = 10_000.0
    logits_soft_cap: float = 0.0

    # --- MLA (deepseek-v3) ---
    mla: bool = False
    mla_q_rank: int = 1536
    mla_kv_rank: int = 512
    mla_rope_dim: int = 64
    mla_nope_dim: int = 128       # per-head non-rope q/k dim
    mla_v_dim: int = 128          # per-head value dim

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0             # per-expert FFN hidden
    first_dense_layers: int = 0   # leading dense layers (deepseek-v3: 3)
    capacity_factor: float = 1.25
    router_noise: float = 0.0

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv_width: int = 4

    # --- hybrid (recurrentgemma: RG-LRU + local attention) ---
    # block pattern repeated over depth; "r" = RG-LRU, "a" = local attention
    hybrid_pattern: str = ""      # e.g. "rra"
    local_window: int = 2048
    rglru_width: int = 0          # 0 -> d_model * ssm_expand is not used; RG uses its own

    # --- enc-dec (seamless) ---
    enc_layers: int = 0
    cross_attention: bool = False

    # --- multimodal stub frontend ---
    frontend: str = "none"        # none | vision | audio
    frontend_tokens: int = 0      # patches / frames supplied by input_specs()

    # --- extras ---
    kv_quant: bool = False        # int8 KV cache (per-token scales)
    mtp: bool = False             # deepseek-v3 multi-token prediction head
    mtp_depth: int = 1
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"             # silu | gelu

    # --- PEFT ---
    lora: Optional[LoRAConfig] = dataclasses.field(default_factory=LoRAConfig)

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    # How many trailing layers are scanned homogeneously (model.py unrolls the
    # leading `first_dense_layers` for deepseek-style mixed stacks).
    @property
    def scanned_layers(self) -> int:
        return self.num_layers - self.first_dense_layers

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_dinner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_dinner // self.ssm_headdim

    # --- KV/state cache bytes per token (per layer type), used by the
    # allocator, the cost model and the roofline analysis. bf16 = 2 bytes. ---
    def kv_bytes_per_token_layer(self) -> int:
        if self.mla:
            return 2 * (self.mla_kv_rank + self.mla_rope_dim)  # latent + rope key
        return 2 * 2 * self.num_kv_heads * self.head_dim        # K and V

    def attn_layer_indices(self) -> Tuple[int, ...]:
        """Indices of layers that own a growing KV cache."""
        if self.family in ("ssm",):
            return ()
        if self.family == "hybrid" and self.hybrid_pattern:
            p = self.hybrid_pattern
            return tuple(i for i in range(self.num_layers) if p[i % len(p)] == "a")
        return tuple(range(self.num_layers))

    def effective_cache_len(self, seq_len: int) -> int:
        """Physical KV length per attention layer at context `seq_len`."""
        if self.attn_type == "swa" and self.window:
            return min(seq_len, self.window)
        if self.family == "hybrid":
            return min(seq_len, self.local_window)
        return seq_len

    def cache_bytes_per_token(self, seq_len: int = 1) -> int:
        """Marginal KV bytes per *new* token across layers (caches that grow)."""
        n_attn = len(self.attn_layer_indices())
        return n_attn * self.kv_bytes_per_token_layer()

    def state_bytes(self) -> int:
        """Fixed-size recurrent state bytes per sequence (SSM / RG-LRU)."""
        total = 0
        if self.family == "ssm":
            per_layer = 2 * self.ssm_nheads * self.ssm_headdim * self.ssm_state
            per_layer += 2 * self.ssm_dinner * (self.ssm_conv_width - 1)
            total += self.num_layers * per_layer
        if self.family == "hybrid" and self.hybrid_pattern:
            n_rec = self.num_layers - len(self.attn_layer_indices())
            total += n_rec * 2 * self.d_model * self.ssm_expand
        return total

    # --- parameter counts (analytic; cross-checked against init in tests) ---
    def param_count(self) -> int:
        d, ff, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd, H, KV = self.head_dim, self.num_heads, self.num_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.mla:
            per_attn = (
                d * self.mla_q_rank
                + self.mla_q_rank * H * (self.mla_nope_dim + self.mla_rope_dim)
                + d * (self.mla_kv_rank + self.mla_rope_dim)
                + self.mla_kv_rank * H * (self.mla_nope_dim + self.mla_v_dim)
                + H * self.mla_v_dim * d
            )
        per_ffn = 3 * d * ff
        total = emb
        if self.family == "ssm":
            dinner = self.ssm_dinner
            nh = self.ssm_nheads
            per_layer = (
                d * (2 * dinner + 2 * self.ssm_state + nh)   # in_proj (x,z,B,C,dt)
                + self.ssm_conv_width * (dinner + 2 * self.ssm_state)
                + 3 * nh                                      # A, dt_bias, D
                + dinner * d                                  # out_proj
                + 2 * d                                       # norms
            )
            return emb + L * per_layer
        for i in range(self.num_layers):
            is_moe = self.moe and i >= self.first_dense_layers
            kind = self.layer_kind(i)
            if kind == "rglru":
                w = self.rglru_width or d
                nb = 16 if w % 16 == 0 else 1
                total += (d * 2 * w          # in_y, in_x
                          + 5 * w            # conv w(4) + bias
                          + 2 * w * (w // nb)  # block-diag gates
                          + w                # Lambda
                          + w * d            # out_proj
                          + 3 * d * ff       # Griffin block MLP
                          + 2 * d)           # norms
                continue
            total += per_attn + 2 * d
            if is_moe:
                total += d * self.num_experts                        # router
                total += self.num_experts * 3 * d * self.moe_d_ff    # routed
                total += self.num_shared_experts * 3 * d * self.moe_d_ff
            else:
                total += per_ffn
        if self.enc_layers:
            total += self.enc_layers * (per_attn + per_ffn + 2 * d)
        if self.cross_attention:
            total += self.num_layers * (per_attn + d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        routed_inactive = (
            (self.num_layers - self.first_dense_layers)
            * (self.num_experts - self.top_k)
            * 3 * self.d_model * self.moe_d_ff
        )
        return full - routed_inactive

    def layer_kind(self, i: int) -> str:
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.hybrid_pattern:
            p = self.hybrid_pattern
            return "rglru" if p[i % len(p)] == "r" else "attn"
        if self.moe and i >= self.first_dense_layers:
            return "moe"
        return "attn"

    def lora_param_count(self) -> int:
        if self.lora is None:
            return 0
        r = self.lora.rank
        d, ff = self.d_model, self.d_ff
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        n = 0
        t = self.lora.targets
        per_layer = 0
        if "q" in t:
            per_layer += r * (d + H * hd)
        if "k" in t:
            per_layer += r * (d + KV * hd)
        if "v" in t:
            per_layer += r * (d + KV * hd)
        if "o" in t:
            per_layer += r * (H * hd + d)
        ffh = self.moe_d_ff if self.moe else self.d_ff
        if "gate" in t:
            per_layer += r * (d + ffh)
        if "up" in t:
            per_layer += r * (d + ffh)
        if "down" in t:
            per_layer += r * (ffh + d)
        return self.num_layers * per_layer


def scale_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build the reduced smoke-test sibling of a full config (same family/
    feature flags, tiny dims)."""
    base = dict(
        num_layers=min(cfg.num_layers, 2 + cfg.first_dense_layers),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        window=min(cfg.window, 64) if cfg.window else 0,
        local_window=64,
        mla_q_rank=64,
        mla_kv_rank=32,
        mla_rope_dim=16,
        mla_nope_dim=32,
        mla_v_dim=32,
        num_experts=min(cfg.num_experts, 4),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=64 if cfg.moe else 0,
        first_dense_layers=min(cfg.first_dense_layers, 1),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_headdim=16,
        ssm_chunk=8,
        enc_layers=min(cfg.enc_layers, 2),
        frontend_tokens=min(cfg.frontend_tokens, 8),
        lora=LoRAConfig(rank=4, targets=cfg.lora.targets if cfg.lora else ()),
        name=cfg.name + "-smoke",
    )
    if cfg.family == "hybrid":
        base["num_layers"] = 3
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
