"""Mixture-of-Experts FFN with capacity-based, sort-ranked dispatch.

Expert-parallel by construction: the expert dimension of all expert tensors
carries the "expert" logical axis (-> mesh "model"); tokens dispatch within
*groups* (GShard-style, group = batch row) so the dispatched activation
tensor (G, E, C, d) spreads over BOTH mesh axes (G->data, E->model) — at
deepseek-v3 train scale that is the difference between 586MB and 9.4GB per
chip of transient dispatch state.

Rank-within-expert uses argsort (megablocks-style), NOT the GShard one-hot
cumsum: O(T·k) memory instead of O(T·k·E), and dispatch FLOPs stay at
O(T·k·d) gather/scatter instead of the O(T²) one-hot einsums.

Routing: softmax top-k (mixtral) or sigmoid top-k + renorm (deepseek-v3
style), plus optional always-on shared experts.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig


def moe_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * s).astype(jnp.float32),
        "gate": (jax.random.normal(ks[1], (E, d, ff)) * s).astype(dtype),
        "up": (jax.random.normal(ks[2], (E, d, ff)) * s).astype(dtype),
        "down": (jax.random.normal(ks[3], (E, ff, d)) * ff ** -0.5).astype(dtype),
    }
    if cfg.num_shared_experts:
        sf = cfg.num_shared_experts * ff
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": (jax.random.normal(k1, (d, sf)) * s).astype(dtype),
            "up": (jax.random.normal(k2, (d, sf)) * s).astype(dtype),
            "down": (jax.random.normal(k3, (sf, d)) * sf ** -0.5).astype(dtype),
        }
    return p


def _rank_in_expert(e_flat: jax.Array, E: int) -> jax.Array:
    """Position of each assignment within its expert, per group.

    e_flat: (G, A) int32 expert ids. Returns (G, A) int32 ranks.
    Sort-based: O(A log A) compute, O(A) memory (vs O(A*E) one-hot cumsum).
    """
    G, A = e_flat.shape
    order = jnp.argsort(e_flat, axis=1, stable=True)           # (G, A)
    counts = jnp.zeros((G, E), jnp.int32).at[
        jnp.arange(G)[:, None], e_flat].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts               # (G, E)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    pos_sorted = jnp.arange(A)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=1)
    ranks = jnp.zeros_like(e_flat).at[
        jnp.arange(G)[:, None], order].set(pos_sorted)
    return ranks


def moe_forward(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                router_type: str = "softmax",
                lora=None, lora_scale: float = 0.0,
                capacity_factor: Optional[float] = None,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (y, aux). aux carries load-balance metrics/losses."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    cf = capacity_factor if capacity_factor is not None else cfg.capacity_factor
    # group = batch row when rows are long enough for capacity to be
    # meaningful; otherwise one global group (e.g. decode steps, S == 1)
    if S * k >= 2 * E:
        G, T = B, S
        C = max(int(round(T * k * cf / E)), 1)
    else:
        # decode / tiny batches: generous capacity (4x mean load) so drops
        # need extreme routing skew, while the dispatch buffer stays small
        # even at E=256 (C=T would be 470GB for deepseek-v3 decode_32k)
        G, T = 1, B * S
        C = min(T, max(8, 4 * (-(-T * k // E))))

    xt = x.reshape(G, T, d)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    if router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(scores, k)                    # (G, T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    A = T * k
    e_flat = top_i.reshape(G, A)
    ranks = _rank_in_expert(e_flat, E)
    keep = ranks < C
    pos_c = jnp.minimum(ranks, C - 1)
    tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k)).reshape(A)
    gidx = jnp.arange(G)[:, None]

    # --- slot plan: route INDICES, never an (G, T*k, d) activation tensor
    # (that intermediate is k x all token bytes and was observed replicated
    # in f32 at 224 GiB/device for deepseek-v3 prefill)
    slot_tok = jnp.full((G, E, C), -1, jnp.int32).at[
        gidx, e_flat, pos_c].max(jnp.where(keep, tok[None, :], -1))
    slot_w = jnp.zeros((G, E, C), jnp.float32).at[gidx, e_flat, pos_c].add(
        jnp.where(keep, top_w.reshape(G, A), 0.0))
    xt = constrain(xt, ("batch", None, None))

    # --- dispatch: direct (G, E, C, d) gather -----------------------------
    flat_ids = jnp.maximum(slot_tok, 0).reshape(G, E * C)
    xe = jnp.take_along_axis(xt, flat_ids[..., None], axis=1)  # (G, EC, d)
    xe = jnp.where((slot_tok >= 0).reshape(G, E * C, 1), xe, 0)
    xe = constrain(xe.reshape(G, E, C, d), ("batch", "expert", None, None))

    # --- expert FFN (grouped GEMM) ---------------------------------------
    g = jnp.einsum("gecd,edf->gecf", xe, p["gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, p["up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, ("batch", "expert", None, None))
    ye = jnp.einsum("gecf,efd->gecd", h, p["down"].astype(x.dtype))
    ye = constrain(ye, ("batch", "expert", None, None))

    # --- combine: k strided gathers back to tokens ------------------------
    # (an add-scatter here makes GSPMD replicate the full (G,T,d) output and
    # all-reduce it — 28 GiB/device at deepseek prefill scale; gathers stay
    # batch-sharded)
    ye_flat = ye.reshape(G, E * C, d)
    slot_of = e_flat * C + pos_c                              # (G, A)
    w_keep = jnp.where(keep, top_w.reshape(G, A), 0.0)
    y = jnp.zeros((G, T, d), jnp.float32)
    for ki in range(k):
        idx = slot_of[:, ki::k]                               # (G, T)
        wk = w_keep[:, ki::k]
        part = jnp.take_along_axis(ye_flat, idx[..., None], axis=1)
        y = y + part.astype(jnp.float32) * wk[..., None]
    y = y.astype(x.dtype).reshape(B, S, d)
    y = constrain(y, ("batch", "seq_sp", None))

    if cfg.num_shared_experts and "shared" in p:
        sh = p["shared"]
        y = y + L.glu_mlp(x, sh["gate"], sh["up"], sh["down"], act=cfg.act,
                          lora=lora, lora_scale=lora_scale)

    # --- aux: load-balance loss (Switch-style) + drop fraction -----------
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))       # (E,)
    ce = jnp.sum(jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32),
                 axis=(0, 1)) / (G * T)
    aux = {
        "lb_loss": E * jnp.sum(me * ce),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux
