"""LoRA adapters (the PEFT workload Harli co-locates with decode).

Adapters are a *parallel pytree* mirroring the model's layer-stack structure
(leading layer axis on every leaf) so they scan together with base params.
Trainable leaves are fp32 (cast to activation dtype on use); base weights stay
frozen bf16 — this is what makes the finetune task memory-light (paper §2.1).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import LoRAConfig, ModelConfig


def _target_dims(cfg: ModelConfig, kind: str) -> Dict[str, Tuple[int, int]]:
    """name -> (d_in, d_out) of the adapted projection for a layer kind."""
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    t = cfg.lora.targets if cfg.lora else ()
    out: Dict[str, Tuple[int, int]] = {}
    if kind in ("attn", "moe", "xattn"):
        if cfg.mla:
            if "q" in t:
                out["q"] = (cfg.mla_q_rank,
                            H * (cfg.mla_nope_dim + cfg.mla_rope_dim))
            if "o" in t:
                out["o"] = (H * cfg.mla_v_dim, d)
        else:
            if "q" in t:
                out["q"] = (d, H * hd)
            if "k" in t:
                out["k"] = (d, KV * hd)
            if "v" in t:
                out["v"] = (d, KV * hd)
            if "o" in t:
                out["o"] = (H * hd, d)
    if kind in ("attn", "rglru"):
        ff = cfg.d_ff
        if "gate" in t:
            out["gate"] = (d, ff)
        if "up" in t:
            out["up"] = (d, ff)
        if "down" in t:
            out["down"] = (ff, d)
    if kind == "moe" and cfg.num_shared_experts:
        sf = cfg.num_shared_experts * cfg.moe_d_ff
        if "gate" in t:
            out["gate"] = (d, sf)
        if "up" in t:
            out["up"] = (d, sf)
        if "down" in t:
            out["down"] = (sf, d)
    if kind == "ssm":
        # parallel low-rank adapter across the whole mixer block (standard
        # PEFT practice for SSMs: LoRA on the block I/O path)
        out["ssm_io"] = (d, d)
    if kind == "rglru":
        out["rg_io"] = (d, d)
    return out


def init_layer_adapters(key, cfg: ModelConfig, kind: str, n_layers: int = 0,
                        dtype=jnp.float32) -> Dict:
    """Adapters for one layer kind; n_layers>0 adds a leading stack axis."""
    r = cfg.lora.rank
    dims = _target_dims(cfg, kind)
    out = {}
    for name, (din, dout) in dims.items():
        key, ka = jax.random.split(key)
        shape_a = (n_layers, din, r) if n_layers else (din, r)
        shape_b = (n_layers, r, dout) if n_layers else (r, dout)
        out[name] = {
            "a": (jax.random.normal(ka, shape_a) * din ** -0.5).astype(dtype),
            "b": jnp.zeros(shape_b, dtype),   # B=0 -> adapters start as no-op
        }
    return out


def lora_scale(cfg: ModelConfig) -> float:
    return cfg.lora.alpha / cfg.lora.rank if cfg.lora else 0.0


def _is_leaf(v) -> bool:
    return isinstance(v, dict) and set(v) == {"a", "b"} and not isinstance(
        v["a"], dict)


def slice_adapters(adapters: Optional[Dict], i) -> Optional[Dict]:
    """Take layer i from a stacked adapter tree -> nested {name: (A, B)}."""
    if adapters is None:
        return None
    return {k: (v["a"][i], v["b"][i]) if _is_leaf(v) else slice_adapters(v, i)
            for k, v in adapters.items()}


def as_pairs(adapters: Optional[Dict]) -> Optional[Dict]:
    """Unstacked adapter dict -> nested {name: (A, B)}."""
    if adapters is None:
        return None
    return {k: (v["a"], v["b"]) if _is_leaf(v) else as_pairs(v)
            for k, v in adapters.items()}


def adapter_count(adapters) -> int:
    return sum(x.size for x in jax.tree.leaves(adapters))
