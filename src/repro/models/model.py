"""Model assembly for all assigned families.

Params layout (pytree):
  {"embed": (V, d), "final_norm": (d,), ["unembed": (V, d)],
   "pre":  [layer, ...]     # unrolled leading layers (deepseek dense head)
   "scan": layer_stack,      # homogeneous stack, leading axis = n_scan
   "post": [layer, ...],     # unrolled trailing layers (hybrid remainder)
   ["enc": {"scan": enc_stack, "final_norm": (d,)}],
   ["mtp": {...}]}

Layers are scanned with ``jax.lax.scan`` (keeps HLO small at 61-layer scale);
hybrid models scan a ("r","r","a") *superblock*. Caches/adapters mirror the
same pre/scan/post structure so they scan together with params.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import lora as LR
from repro.models import moe as M
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.config import ModelConfig

Params = Dict[str, Any]

MOE_AUX_COEF = 0.01
MTP_COEF = 0.3


# ===================================================================== init
def _mlp_init(key, cfg, dtype, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s = d ** -0.5
    return {
        "gate": (jax.random.normal(k1, (d, ff)) * s).astype(dtype),
        "up": (jax.random.normal(k2, (d, ff)) * s).astype(dtype),
        "down": (jax.random.normal(k3, (ff, d)) * ff ** -0.5).astype(dtype),
    }


def _attn_init(key, cfg, dtype):
    return A.mla_init(key, cfg, dtype) if cfg.mla else A.attn_init(key, cfg, dtype)


def layer_init(key, cfg: ModelConfig, kind: str, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    ones = jnp.ones((d,), dtype)
    if kind == "ssm":
        return {"ln1": ones, "ssm": SSM.ssm_init(key, cfg, dtype)}
    if kind == "rglru":
        k1, k2 = jax.random.split(key)
        return {"ln1": ones, "rg": RG.rglru_init(k1, cfg, dtype),
                "ln2": ones, "mlp": _mlp_init(k2, cfg, dtype)}
    if kind == "moe":
        k1, k2 = jax.random.split(key)
        return {"ln1": ones, "attn": _attn_init(k1, cfg, dtype),
                "ln2": ones, "moe": M.moe_init(k2, cfg, dtype)}
    if kind == "hybrid_block":
        ks = jax.random.split(key, len(cfg.hybrid_pattern))
        return {f"sub{i}": layer_init(
                    ks[i], cfg, "rglru" if ch == "r" else "attn", dtype)
                for i, ch in enumerate(cfg.hybrid_pattern)}
    if kind == "dec":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"ln1": ones, "attn": _attn_init(k1, cfg, dtype),
                "lnx": ones, "xattn": A.attn_init(k2, cfg, dtype),
                "ln2": ones, "mlp": _mlp_init(k3, cfg, dtype)}
    # "attn" (dense decoder layer) and "enc" (bidirectional encoder layer)
    k1, k2 = jax.random.split(key)
    return {"ln1": ones, "attn": _attn_init(k1, cfg, dtype),
            "ln2": ones, "mlp": _mlp_init(k2, cfg, dtype)}


def _stack_init(key, cfg, kind, n, dtype):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(k, cfg, kind, dtype))(keys)


def _plan(cfg: ModelConfig):
    """(pre_kinds, scan_kind, n_scan, post_kinds) — how depth is laid out."""
    if cfg.family == "hybrid" and cfg.hybrid_pattern:
        plen = len(cfg.hybrid_pattern)
        n_blocks = cfg.num_layers // plen
        rem = cfg.num_layers - n_blocks * plen
        post = ["rglru" if cfg.hybrid_pattern[i] == "r" else "attn"
                for i in range(rem)]
        return [], "hybrid_block", n_blocks, post
    if cfg.family == "ssm":
        return [], "ssm", cfg.num_layers, []
    if cfg.family in ("encdec", "audio") and cfg.cross_attention:
        return [], "dec", cfg.num_layers, []
    if cfg.moe:
        return ["attn"] * cfg.first_dense_layers, "moe", cfg.scanned_layers, []
    return [], "attn", cfg.num_layers, []


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Params:
    pre_kinds, scan_kind, n_scan, post_kinds = _plan(cfg)
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.vocab_size
    p: Params = {
        "embed": (jax.random.normal(keys[0], (V, d)) * d ** -0.5).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
        "pre": [layer_init(k, cfg, kd, dtype) for k, kd in
                zip(jax.random.split(keys[1], max(len(pre_kinds), 1)), pre_kinds)],
        "scan": _stack_init(keys[2], cfg, scan_kind, n_scan, dtype),
        "post": [layer_init(k, cfg, kd, dtype) for k, kd in
                 zip(jax.random.split(keys[3], max(len(post_kinds), 1)), post_kinds)],
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(keys[4], (V, d)) * d ** -0.5
                        ).astype(dtype)
    if cfg.enc_layers:
        p["enc"] = {"scan": _stack_init(keys[5], cfg, "enc", cfg.enc_layers, dtype),
                    "final_norm": jnp.ones((d,), dtype)}
    if cfg.mtp:
        k1, k2 = jax.random.split(keys[6])
        p["mtp"] = {"norm_h": jnp.ones((d,), dtype),
                    "norm_e": jnp.ones((d,), dtype),
                    "proj": (jax.random.normal(k1, (2 * d, d)) * (2 * d) ** -0.5
                             ).astype(dtype),
                    "layer": layer_init(k2, cfg, "attn", dtype)}
    return p


def init_adapters(cfg: ModelConfig, key) -> Params:
    """LoRA adapters mirroring pre/scan/post (fp32 leaves)."""
    pre_kinds, scan_kind, n_scan, post_kinds = _plan(cfg)
    keys = jax.random.split(key, 4)
    if scan_kind == "hybrid_block":
        ks = jax.random.split(keys[1], len(cfg.hybrid_pattern))
        scan_ad = {f"sub{i}": LR.init_layer_adapters(
                       ks[i], cfg, "rglru" if ch == "r" else "attn", n_scan)
                   for i, ch in enumerate(cfg.hybrid_pattern)}
    else:
        kind = {"dec": "attn"}.get(scan_kind, scan_kind)
        scan_ad = LR.init_layer_adapters(keys[1], cfg, kind, n_scan)
    return {
        "pre": [LR.init_layer_adapters(k, cfg, kd)
                for k, kd in zip(jax.random.split(keys[0], max(len(pre_kinds), 1)),
                                 pre_kinds)],
        "scan": scan_ad,
        "post": [LR.init_layer_adapters(k, cfg, kd)
                 for k, kd in zip(jax.random.split(keys[2], max(len(post_kinds), 1)),
                                  post_kinds)],
    }


# ==================================================================== cache
def layer_cache(cfg: ModelConfig, kind: str, batch: int, s_max: int,
                enc_len: int = 0, dtype=jnp.bfloat16):
    if kind == "ssm":
        return SSM.make_ssm_state(cfg, batch)
    if kind == "rglru":
        return RG.make_rglru_state(cfg, batch)
    if kind == "hybrid_block":
        return {f"sub{i}": layer_cache(
                    cfg, "rglru" if ch == "r" else "attn", batch, s_max,
                    enc_len, dtype)
                for i, ch in enumerate(cfg.hybrid_pattern)}
    if kind == "dec":
        c = {"self": A.make_cache(cfg, batch, s_max, dtype)}
        c["xk"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        c["xv"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        return c
    window = _layer_window(cfg, kind)
    return A.make_cache(cfg, batch, s_max, dtype, window=window,
                        quantized=cfg.kv_quant)


def _layer_window(cfg: ModelConfig, kind: str) -> int:
    if cfg.family == "hybrid":
        return cfg.local_window
    if cfg.attn_type == "swa":
        return cfg.window
    return 0


def init_cache(cfg: ModelConfig, batch: int, s_max: int, enc_len: int = 0,
               dtype=jnp.bfloat16) -> Params:
    pre_kinds, scan_kind, n_scan, post_kinds = _plan(cfg)

    def stack(kind):
        one = layer_cache(cfg, kind, batch, s_max, enc_len, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape), one)

    return {
        "pre": [layer_cache(cfg, kd, batch, s_max, enc_len, dtype)
                for kd in pre_kinds],
        "scan": stack(scan_kind),
        "post": [layer_cache(cfg, kd, batch, s_max, enc_len, dtype)
                 for kd in post_kinds],
    }


# ============================================================ layer apply
def apply_layer(lp: Params, x, positions, cfg: ModelConfig, kind: str, *,
                mode: str,                       # "full" | "prefill" | "decode"
                cache=None, lora=None, scale: float = 0.0,
                enc_out=None, decode_attn_fn=None, use_kernels=False):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "hybrid_block":
        new_cache = {} if cache is not None else None
        for i, ch in enumerate(cfg.hybrid_pattern):
            sub = "rglru" if ch == "r" else "attn"
            c = None if cache is None else cache[f"sub{i}"]
            x, nc, a = apply_layer(
                lp[f"sub{i}"], x, positions, cfg, sub, mode=mode, cache=c,
                lora=None if lora is None else lora.get(f"sub{i}"),
                scale=scale, decode_attn_fn=decode_attn_fn,
                use_kernels=use_kernels)
            if new_cache is not None:
                new_cache[f"sub{i}"] = nc
            aux += a
        return x, new_cache, aux

    lora_d = lora  # callers pass pairs-form ({name: (A, B)}, possibly nested)

    if kind == "ssm":
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        if mode == "decode":
            out, nc = SSM.ssm_decode(lp["ssm"], h, cache, cfg)
        else:
            out, nc = SSM.ssm_prefill(lp["ssm"], h, cfg, state=cache,
                                      use_kernel=use_kernels)
        out = _parallel_lora(h, out, lora_d, "ssm_io", scale)
        return x + out, nc, aux

    if kind == "rglru":
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        if mode == "decode":
            out, nc = RG.rglru_decode(lp["rg"], h, cache, cfg)
        else:
            out, nc = RG.rglru_forward(lp["rg"], h, cfg, state=cache)
        out = _parallel_lora(h, out, lora_d, "rg_io", scale)
        x = x + out
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.glu_mlp(h, lp["mlp"]["gate"], lp["mlp"]["up"],
                          lp["mlp"]["down"], act=cfg.act,
                          lora=lora_d, lora_scale=scale)
        return x, nc, aux

    # --- attention-bearing layers ("attn", "moe", "enc", "dec") ----------
    window = _layer_window(cfg, kind)
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla:
        if mode == "decode":
            attn_out, nc = A.mla_decode(lp["attn"], h, positions, cache, cfg,
                                        lora=lora_d, lora_scale=scale)
        else:
            attn_out, nc = A.mla_prefill(lp["attn"], h, positions, cfg,
                                         cache=cache, lora=lora_d,
                                         lora_scale=scale)
    elif kind == "enc":
        q, k, v = A._project_qkv(lp["attn"], h, cfg, lora_d, scale)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = L.flash_attention(q, k, v, causal=False,
                              soft_cap=cfg.logits_soft_cap)
        attn_out = A._out_proj(lp["attn"], o, cfg, lora_d, scale)
        nc = None
    elif mode == "decode":
        self_cache = cache["self"] if kind == "dec" else cache
        attn_out, nc_self = A.attn_decode(
            lp["attn"], h, positions, self_cache, cfg, window=window,
            lora=lora_d, lora_scale=scale, decode_attn_fn=decode_attn_fn)
        nc = {"self": nc_self, "xk": cache["xk"], "xv": cache["xv"]} \
            if kind == "dec" else nc_self
    else:
        self_cache = cache["self"] if (kind == "dec" and cache is not None) \
            else cache
        attn_out, nc_self = A.attn_prefill(
            lp["attn"], h, positions, cfg, window=window, cache=self_cache,
            lora=lora_d, lora_scale=scale)
        nc = {"self": nc_self} if kind == "dec" else nc_self
    x = x + attn_out

    if kind == "dec":                       # cross attention
        h = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
        x_out, xk, xv = _cross_attention(lp["xattn"], h, cfg, mode,
                                         cache, enc_out)
        x = x + x_out
        if isinstance(nc, dict) and cache is not None:
            nc["xk"], nc["xv"] = xk, xv

    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if kind == "moe":
        router_type = "sigmoid" if cfg.mla else "softmax"
        mo, maux = M.moe_forward(lp["moe"], h, cfg, router_type=router_type,
                                 lora=lora_d, lora_scale=scale)
        x = x + mo
        aux += maux["lb_loss"]
    else:
        x = x + L.glu_mlp(h, lp["mlp"]["gate"], lp["mlp"]["up"],
                          lp["mlp"]["down"], act=cfg.act,
                          lora=lora_d, lora_scale=scale)
    return x, nc, aux


def _cross_attention(p, h, cfg, mode, cache, enc_out):
    """Decoder->encoder attention; K/V cached at prefill."""
    B = h.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,do->bso", h, p["wq"].astype(h.dtype)
                   ).reshape(B, -1, H, hd)
    if enc_out is not None:                         # prefill: build K/V
        xk = jnp.einsum("bsd,do->bso", enc_out, p["wk"].astype(h.dtype)
                        ).reshape(B, -1, KV, hd)
        xv = jnp.einsum("bsd,do->bso", enc_out, p["wv"].astype(h.dtype)
                        ).reshape(B, -1, KV, hd)
    else:
        xk, xv = cache["xk"], cache["xv"]
    o = L.flash_attention(q, xk, xv, causal=False)
    o = o.reshape(B, -1, H * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(h.dtype))
    return out, xk, xv


def _parallel_lora(h, out, lora_d, name, scale):
    """Parallel low-rank adapter on a mixer block's I/O path."""
    if lora_d and name in lora_d:
        a, b = lora_d[name]
        out = out + scale * jnp.einsum(
            "...r,rd->...d", jnp.einsum("...d,dr->...r", h, a.astype(h.dtype)),
            b.astype(h.dtype))
    return out


# ================================================================= drivers
def _kinds(cfg: ModelConfig):
    return _plan(cfg)


def _embed_inputs(params, cfg: ModelConfig, batch: Dict):
    """Token (+frontend) embedding. Returns (x, positions, text_offset)."""
    tokens = batch["tokens"]
    x = L.embed(tokens, params["embed"])
    offset = 0
    if cfg.frontend != "none" and batch.get("frontend") is not None:
        fe = batch["frontend"].astype(x.dtype)       # (B, P, d) stub embeds
        x = jnp.concatenate([fe, x], axis=1)
        offset = fe.shape[1]
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = constrain(x, ("batch", "seq_sp", None))
    return x, positions, offset


def _encode(params, cfg: ModelConfig, batch: Dict, use_kernels=False):
    """Run the (bidirectional) encoder over stub frame embeddings."""
    enc_in = batch["enc_frames"].astype(params["embed"].dtype)  # (B, Se, d)
    B, Se, _ = enc_in.shape
    positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    x = constrain(enc_in, ("batch", "seq_sp", None))

    def body(h, lp):
        h, _, _ = apply_layer(lp, h, positions, cfg, "enc", mode="full",
                              use_kernels=use_kernels)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc"]["scan"])
    return L.rms_norm(x, params["enc"]["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: Dict, *,
            adapters=None, use_kernels: bool = False, remat: bool = False,
            return_hidden: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train / eval). Returns (logits, aux_loss);
    with return_hidden=True returns the normed final hidden states instead
    of logits (loss_fn fuses the projection into chunked CE)."""
    pre_kinds, scan_kind, n_scan, post_kinds = _plan(cfg)
    x, positions, offset = _embed_inputs(params, cfg, batch)
    scale = LR.lora_scale(cfg)
    enc_out = None
    if cfg.enc_layers:
        enc_out = _encode(params, cfg, batch, use_kernels)

    aux = jnp.zeros((), jnp.float32)
    for i, kd in enumerate(pre_kinds):
        ad = None if adapters is None else LR.as_pairs(adapters["pre"][i])
        x, _, a = apply_layer(params["pre"][i], x, positions, cfg, kd,
                              mode="full", lora=ad, scale=scale,
                              enc_out=enc_out, use_kernels=use_kernels)
        aux += a

    def body(carry, xs):
        h, aux_c = carry
        lp, ad_stacked = xs
        ad = None if ad_stacked is None else _pairs_from_sliced(ad_stacked)
        h, _, a = apply_layer(lp, h, positions, cfg, scan_kind, mode="full",
                              lora=ad, scale=scale, enc_out=enc_out,
                              use_kernels=use_kernels)
        return (h, aux_c + a), None

    body_fn = jax.checkpoint(body) if remat else body
    scan_ad = None if adapters is None else adapters["scan"]
    if scan_ad is None:
        (x, aux), _ = jax.lax.scan(
            lambda c, lp: body_fn(c, (lp, None)), (x, aux), params["scan"])
    else:
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), (params["scan"], scan_ad))

    for i, kd in enumerate(post_kinds):
        ad = None if adapters is None else LR.as_pairs(adapters["post"][i])
        x, _, a = apply_layer(params["post"][i], x, positions, cfg, kd,
                              mode="full", lora=ad, scale=scale,
                              enc_out=enc_out, use_kernels=use_kernels)
        aux += a

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x[:, offset:], aux
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.lm_logits(x[:, offset:], table)
    return logits, aux


def _pairs_from_sliced(ad_sliced) -> Dict:
    """Stacked adapters arrive in scan with the layer axis already consumed."""
    return {k: ((v["a"], v["b"]) if isinstance(v, dict) and set(v) == {"a", "b"}
                else _pairs_from_sliced(v))
            for k, v in ad_sliced.items()}


def loss_fn(params, cfg: ModelConfig, batch: Dict, *, adapters=None,
            use_kernels=False, remat: bool = True):
    """Cross-entropy (+ MoE aux + MTP) loss for (PEFT) training.

    The final projection is fused into a chunked CE (never materializes the
    (B, S, V) logits — decisive for non-16-divisible vocabs like seamless's
    256206, which would otherwise replicate a 537GB tensor)."""
    hidden, aux = forward(params, cfg, batch, adapters=adapters,
                          use_kernels=use_kernels, remat=remat,
                          return_hidden=True)
    labels = batch["labels"]
    mask = batch.get("mask")
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    ce = L.chunked_softmax_xent(
        hidden[:, :-1], table, labels[:, 1:],
        None if mask is None else mask[:, 1:])
    total = ce + MOE_AUX_COEF * aux / max(cfg.num_layers, 1)
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp and "mtp" in params:
        mtp_ce = _mtp_loss(params, cfg, batch, None)
        total = total + MTP_COEF * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return total, metrics


def _mtp_loss(params, cfg, batch, logits):
    """DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1}).
    Approximated at the head: reuse final logits' hidden via embeddings."""
    mp = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    emb_next = L.embed(tokens[:, 1:], params["embed"])
    # hidden proxy: embed current token through the shared table (cheap MTP
    # variant; the trunk layer provides the model capacity)
    h = L.embed(tokens[:, :-1], params["embed"])
    h = jnp.concatenate([L.rms_norm(h, mp["norm_h"], cfg.norm_eps),
                         L.rms_norm(emb_next, mp["norm_e"], cfg.norm_eps)],
                        axis=-1)
    h = jnp.einsum("bsd,do->bso", h, mp["proj"].astype(h.dtype))
    positions = jnp.broadcast_to(jnp.arange(S - 1, dtype=jnp.int32), (B, S - 1))
    h, _, _ = apply_layer(mp["layer"], h, positions, cfg, "attn", mode="full")
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    mtp_logits = L.lm_logits(h, table)
    return L.cross_entropy(mtp_logits[:, :-1], labels[:, 2:])


def prefill(params, cfg: ModelConfig, batch: Dict, cache, *,
            use_kernels: bool = False):
    """Prompt processing: forward + cache fill. Returns (last_logits, cache)."""
    pre_kinds, scan_kind, n_scan, post_kinds = _plan(cfg)
    x, positions, offset = _embed_inputs(params, cfg, batch)
    enc_out = _encode(params, cfg, batch, use_kernels) if cfg.enc_layers else None

    new_cache = {"pre": [], "post": []}
    for i, kd in enumerate(pre_kinds):
        x, nc, _ = apply_layer(params["pre"][i], x, positions, cfg, kd,
                               mode="prefill", cache=cache["pre"][i],
                               enc_out=enc_out, use_kernels=use_kernels)
        new_cache["pre"].append(nc)

    # the stacked cache is a loop CARRY updated in place (aliasable with the
    # donated input cache) — emitting it as scan ys would materialize a
    # second full cache buffer (and XLA pads the accumulation in f32)
    def body(carry, lp):
        h, cstack, i = carry
        lc = jax.tree.map(lambda t: jax.lax.dynamic_index_in_dim(
            t, i, 0, keepdims=False), cstack)
        h, nc, _ = apply_layer(lp, h, positions, cfg, scan_kind,
                               mode="prefill", cache=lc, enc_out=enc_out,
                               use_kernels=use_kernels)
        cstack = jax.tree.map(
            lambda t, n: jax.lax.dynamic_update_index_in_dim(
                t, n.astype(t.dtype), i, 0), cstack, nc)
        return (h, cstack, i + 1), None

    (x, scan_cache, _), _ = jax.lax.scan(
        body, (x, cache["scan"], jnp.zeros((), jnp.int32)), params["scan"])
    new_cache["scan"] = scan_cache

    for i, kd in enumerate(post_kinds):
        x, nc, _ = apply_layer(params["post"][i], x, positions, cfg, kd,
                               mode="prefill", cache=cache["post"][i],
                               enc_out=enc_out, use_kernels=use_kernels)
        new_cache["post"].append(nc)

    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.lm_logits(x, table)[:, 0]
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens, positions, cache, *,
                use_kernels: bool = False, decode_attn_fn=None):
    """One decode token. tokens/positions: (B,). Returns (logits (B,V), cache)."""
    pre_kinds, scan_kind, n_scan, post_kinds = _plan(cfg)
    x = L.embed(tokens[:, None], params["embed"])     # (B, 1, d)
    x = constrain(x, ("batch", None, None))
    if decode_attn_fn is None and use_kernels:
        from repro.kernels import ops as kops
        decode_attn_fn = kops.decode_attention

    new_cache = {"pre": [], "post": []}
    for i, kd in enumerate(pre_kinds):
        x, nc, _ = apply_layer(params["pre"][i], x, positions, cfg, kd,
                               mode="decode", cache=cache["pre"][i],
                               decode_attn_fn=decode_attn_fn,
                               use_kernels=use_kernels)
        new_cache["pre"].append(nc)

    # cache as in-place-updated carry (see prefill note)
    def body(carry, lp):
        h, cstack, i = carry
        lc = jax.tree.map(lambda t: jax.lax.dynamic_index_in_dim(
            t, i, 0, keepdims=False), cstack)
        h, nc, _ = apply_layer(lp, h, positions, cfg, scan_kind, mode="decode",
                               cache=lc, decode_attn_fn=decode_attn_fn,
                               use_kernels=use_kernels)
        cstack = jax.tree.map(
            lambda t, n: jax.lax.dynamic_update_index_in_dim(
                t, n.astype(t.dtype), i, 0), cstack, nc)
        return (h, cstack, i + 1), None

    (x, scan_cache, _), _ = jax.lax.scan(
        body, (x, cache["scan"], jnp.zeros((), jnp.int32)), params["scan"])
    new_cache["scan"] = scan_cache

    for i, kd in enumerate(post_kinds):
        x, nc, _ = apply_layer(params["post"][i], x, positions, cfg, kd,
                               mode="decode", cache=cache["post"][i],
                               decode_attn_fn=decode_attn_fn,
                               use_kernels=use_kernels)
        new_cache["post"].append(nc)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.lm_logits(x, table)[:, 0]
    return logits, new_cache
