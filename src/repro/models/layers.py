"""Shared neural building blocks: norms, RoPE, MLPs, flash attention (jnp).

The chunked flash attention here is the *reference* implementation that the
Pallas kernels are validated against, and is the production path for prefill /
training (XLA fuses it well on TPU); decode uses kernels/decode_attention.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

DEFAULT_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------- norms ----
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- MLPs ----
def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[name]


def glu_mlp(x, gate_w, up_w, down_w, act: str = "silu",
            lora=None, lora_scale: float = 0.0):
    """SwiGLU / GeGLU MLP with optional fused LoRA deltas.

    lora: dict with optional keys gate/up/down -> (A: (d, r), B: (r, ff))."""
    def proj(h, w, key, out_logical):
        y = jnp.einsum("...d,df->...f", h, w.astype(h.dtype))
        if lora is not None and key in lora:
            a, b = lora[key]
            y = y + lora_scale * jnp.einsum(
                "...r,rf->...f", jnp.einsum("...d,dr->...r", h, a.astype(h.dtype)),
                b.astype(h.dtype))
        return constrain(y, out_logical) if y.ndim == 3 else y
    g = proj(x, gate_w, "gate", ("batch", None, "ff"))
    u = proj(x, up_w, "up", ("batch", None, "ff"))
    h = _act(act)(g.astype(jnp.float32)).astype(x.dtype) * u
    return proj(h, down_w, "down", ("batch", "seq_sp", None))


# --------------------------------------------------- flash attention -------
def flash_attention(
    q: jax.Array,                # (B, Sq, H, hd)
    k: jax.Array,                # (B, Sk, KV, hd)
    v: jax.Array,                # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    q_offset: Optional[jax.Array] = None,   # absolute pos of q[:,0] (decode/chunks)
    window: int = 0,             # sliding-window size (0 = full)
    soft_cap: float = 0.0,
    scale: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Chunked online-softmax attention (GQA-aware), O(S) memory in BOTH
    directions: a custom VJP recomputes the softmax blocks in the backward
    pass (plain autodiff through the chunk scans would save O(S^2) weights
    — observed as 20GiB/device buffers in the 32k-train dry-run).
    """
    B, Sq, H, hd = q.shape
    scale_v = scale if scale is not None else hd ** -0.5
    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32) + \
            (k.shape[1] - Sq if causal else 0)
    f = _make_flash(causal, window, float(soft_cap), float(scale_v),
                    int(q_chunk), int(kv_chunk))
    return f(q, k, v, q_offset)


@functools.lru_cache(maxsize=None)
def _make_flash(causal, window, soft_cap, scale, q_chunk, kv_chunk):
    kw = dict(causal=causal, window=window, soft_cap=soft_cap, scale=scale,
              q_chunk=q_chunk, kv_chunk=kv_chunk)

    @jax.custom_vjp
    def f(q, k, v, q_offset):
        return _flash_fwd(q, k, v, q_offset, **kw)[0]

    def fwd(q, k, v, q_offset):
        o, lse = _flash_fwd(q, k, v, q_offset, **kw)
        return o, (q, k, v, q_offset, o, lse)

    def bwd(res, do):
        q, k, v, q_offset, o, lse = res
        dq, dk, dv = _flash_bwd(q, k, v, q_offset, o, lse, do, **kw)
        return dq, dk, dv, jnp.zeros_like(q_offset)

    f.defvjp(fwd, bwd)
    return f


def _flash_fwd(q, k, v, q_offset, *, causal, window, soft_cap, scale,
               q_chunk, kv_chunk):
    """Returns (o (B,Sq,H,vd), lse (B,KV,g,Sq) fp32)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    vd = v.shape[-1]                       # value head dim may differ (MLA)
    g = H // KV
    scale = scale if scale is not None else hd ** -0.5
    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32) + (Sk - Sq if causal else 0)

    q = q.reshape(B, Sq, KV, g, hd)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    n_q = -(-Sq // q_chunk)
    n_kv = -(-Sk // kv_chunk)
    # Pad sequence dims to chunk multiples.
    q = _pad_seq(q, n_q * q_chunk, 1)
    k = _pad_seq(k, n_kv * kv_chunk, 1)
    v = _pad_seq(v, n_kv * kv_chunk, 1)

    def q_block(carry, qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, 1)
        q_pos = q_offset[:, None] + qi * q_chunk + jnp.arange(q_chunk)[None, :]  # (B, qc)

        def kv_block(acc, ki):
            m, l, o = acc
            kb = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if soft_cap > 0.0:
                s = soft_cap * jnp.tanh(s / soft_cap)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.broadcast_to((kpos < Sk)[None, None, :],
                                    (B, q_chunk, kv_chunk))
            if causal:
                mask = mask & (kpos[None, None, :] <= q_pos[:, :, None])
            if window > 0:
                mask = mask & (kpos[None, None, :] > q_pos[:, :, None] - window)
            s = jnp.where(mask[:, None, None, :, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[:, None, None, :, :], p, 0.0)
            corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            # keep v in its storage dtype (see decode_attn_ref note: an
            # .astype(f32) here becomes a hoisted full-cache f32 copy)
            o_new = o * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, o_new), None

        init = (
            jnp.full((B, KV, g, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((B, KV, g, q_chunk), jnp.float32),
            jnp.zeros((B, KV, g, q_chunk, vd), jnp.float32),
        )
        (m, l, o), _ = jax.lax.scan(kv_block, init, jnp.arange(n_kv))
        o = o / jnp.maximum(l[..., None], 1e-30)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
        o = jnp.moveaxis(o, 3, 1)                        # (B, qc, KV, g, vd)
        return carry, (o.astype(v.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, jnp.arange(n_q))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_q * q_chunk, KV, g, vd)
    # lses: (n_q, B, KV, g, qc) -> (B, KV, g, Sq)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, KV, g, n_q * q_chunk)
    return out[:, :Sq].reshape(B, Sq, H, vd), lse[..., :Sq]


def _flash_bwd(q, k, v, q_offset, o, lse, do, *, causal, window, soft_cap,
               scale, q_chunk, kv_chunk):
    """Recompute-based flash backward (dq, dk, dv), O(S) memory.

    Standard algorithm: per (q-block, kv-block) recompute p from q,k and the
    saved LSE; then
        dv += p^T do ;  dp = do v^T ;  ds = p*(dp - D)  (D = rowsum(do*o)) ;
        [soft-cap chain rule: ds *= 1 - (s_capped/cap)^2] ;
        dq += ds k ;  dk += ds^T q.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    vd = v.shape[-1]
    g = H // KV
    in_dtype = q.dtype
    qr = q.reshape(B, Sq, KV, g, hd)
    dor = do.reshape(B, Sq, KV, g, vd)
    orr = o.reshape(B, Sq, KV, g, vd)
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    n_q = -(-Sq // qc)
    n_kv = -(-Sk // kc)
    qr = _pad_seq(qr, n_q * qc, 1)
    dor = _pad_seq(dor, n_q * qc, 1)
    orr = _pad_seq(orr, n_q * qc, 1)
    kp = _pad_seq(k, n_kv * kc, 1)
    vp = _pad_seq(v, n_kv * kc, 1)
    lse_p = _pad_seq(lse, n_q * qc, 3)   # (B, KV, g, Sq_pad); pad rows = 0
    D = jnp.sum(dor.astype(jnp.float32) * orr.astype(jnp.float32),
                axis=-1)                  # (B, Sq_pad, KV, g)

    def recompute_s(qb, kb, q_pos, kpos):
        s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        cap_grad = 1.0
        if soft_cap > 0.0:
            t = jnp.tanh(s / soft_cap)
            s = soft_cap * t
            cap_grad = 1.0 - t * t
        mask = jnp.broadcast_to((kpos < Sk)[None, None, :],
                                (B, qb.shape[1], kpos.shape[0]))
        if causal:
            mask = mask & (kpos[None, None, :] <= q_pos[:, :, None])
        if window > 0:
            mask = mask & (kpos[None, None, :] > q_pos[:, :, None] - window)
        return s, cap_grad, mask

    def q_block(carry, qi):
        dk_acc, dv_acc = carry
        qb = jax.lax.dynamic_slice_in_dim(qr, qi * qc, qc, 1)
        dob = jax.lax.dynamic_slice_in_dim(dor, qi * qc, qc, 1
                                           ).astype(jnp.float32)
        lseb = jax.lax.dynamic_slice_in_dim(lse_p, qi * qc, qc, 3)
        Db = jax.lax.dynamic_slice_in_dim(D, qi * qc, qc, 1)  # (B,qc,KV,g)
        q_pos = q_offset[:, None] + qi * qc + jnp.arange(qc)[None, :]

        def kv_block(acc, ki):
            dq_b, dk_a, dv_a = acc
            kb = jax.lax.dynamic_slice_in_dim(kp, ki * kc, kc, 1)
            vb = jax.lax.dynamic_slice_in_dim(vp, ki * kc, kc, 1)
            kpos = ki * kc + jnp.arange(kc)
            s, cap_grad, mask = recompute_s(qb, kb, q_pos, kpos)
            lse_safe = jnp.where(jnp.isneginf(lseb), 0.0, lseb)
            p = jnp.exp(s - lse_safe[..., None])          # (B,KV,g,qc,kvc)
            p = jnp.where(mask[:, None, None, :, :], p, 0.0)
            p = jnp.where(jnp.isneginf(lseb)[..., None], 0.0, p)
            # padded q rows (lse padding is zeros, not -inf) must not leak
            # into dk/dv
            qvalid = (qi * qc + jnp.arange(qc)) < Sq
            p = p * qvalid[None, None, None, :, None]
            dp = jnp.einsum("bqkgh,bskh->bkgqs", dob, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - jnp.moveaxis(Db, 1, 3)[..., None]) * cap_grad
            dq_b = dq_b + jnp.einsum(
                "bkgqs,bskh->bqkgh", ds.astype(in_dtype), kb,
                preferred_element_type=jnp.float32) * scale
            dk_a = jax.lax.dynamic_update_slice_in_dim(
                dk_a, jax.lax.dynamic_slice_in_dim(dk_a, ki * kc, kc, 1)
                + jnp.einsum("bkgqs,bqkgh->bskh", ds.astype(in_dtype), qb,
                             preferred_element_type=jnp.float32) * scale,
                ki * kc, 1)
            dv_a = jax.lax.dynamic_update_slice_in_dim(
                dv_a, jax.lax.dynamic_slice_in_dim(dv_a, ki * kc, kc, 1)
                + jnp.einsum("bkgqs,bqkgh->bskh", p.astype(in_dtype), dob,
                             preferred_element_type=jnp.float32),
                ki * kc, 1)
            return (dq_b, dk_a, dv_a), None

        dq_init = jnp.zeros((B, qc, KV, g, hd), jnp.float32)
        (dq_b, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_block, (dq_init, dk_acc, dv_acc), jnp.arange(n_kv))
        return (dk_acc, dv_acc), dq_b.astype(in_dtype)

    dk0 = jnp.zeros((B, n_kv * kc, KV, hd), jnp.float32)
    dv0 = jnp.zeros((B, n_kv * kc, KV, vd), jnp.float32)
    (dk_f, dv_f), dqs = jax.lax.scan(q_block, (dk0, dv0), jnp.arange(n_q))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, n_q * qc, KV, g, hd)
    dq = dq[:, :Sq].reshape(B, Sq, H, hd).astype(in_dtype)
    dk = dk_f[:, :Sk].astype(in_dtype)
    dv = dv_f[:, :Sk].astype(in_dtype)
    return dq, dk, dv


def _pad_seq(x, target, axis):
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def attention_ref(q, k, v, *, causal=True, window=0, soft_cap=0.0,
                  q_offset=None, scale=None):
    """Dense O(S^2) oracle used by tests."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    vd = v.shape[-1]
    g = H // KV
    scale = scale if scale is not None else hd ** -0.5
    if q_offset is None:
        q_offset = jnp.zeros((B,), jnp.int32) + (Sk - Sq if causal else 0)
    qr = q.reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qr, k, preferred_element_type=jnp.float32)
    s = s * scale
    if soft_cap > 0.0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    q_pos = q_offset[:, None] + jnp.arange(Sq)[None]
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((B, Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, None, :] <= q_pos[:, :, None]
    if window > 0:
        mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
    s = jnp.where(mask[:, None, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, vd).astype(v.dtype)


# ------------------------------------------------------------ embeddings ---
def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    return out


def lm_logits(x: jax.Array, table: jax.Array) -> jax.Array:
    """x: (B, S, d); table: (V, d) -> logits (B, S, V) (vocab TP-shardable)."""
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    return constrain(logits, ("batch", None, "vocab"))


def chunked_softmax_xent(x: jax.Array, table: jax.Array, labels: jax.Array,
                         mask=None, chunk: int = 256):
    """Fused final-projection + cross-entropy over sequence chunks.

    Never materializes (B, S, V): each chunk computes its logits, LSE and
    gold score, and the chunk body is rematerialized in backward. Essential
    when V doesn't shard (seamless: 256206 on a 16-way axis -> a replicated
    537GB logits tensor otherwise).

    x: (B, S, d) FINAL hidden states (already normed, already shifted);
    labels: (B, S) aligned with x."""
    B, S, d = x.shape
    c = min(chunk, S)
    n = -(-S // c)
    xp = _pad_seq(x, n * c, 1)
    lp = _pad_seq(labels, n * c, 1)
    mp = jnp.ones((B, n * c), jnp.float32) if mask is None else \
        _pad_seq(mask.astype(jnp.float32), n * c, 1)
    mp = mp * (jnp.arange(n * c)[None, :] < S)

    @jax.checkpoint
    def body(carry, i):
        tot, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(xp, i * c, c, 1)
        ls = jax.lax.dynamic_slice_in_dim(lp, i * c, c, 1)
        ms = jax.lax.dynamic_slice_in_dim(mp, i * c, c, 1)
        logits = jnp.einsum("bsd,vd->bsv", xs, table.astype(xs.dtype)
                            ).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - gold) * ms)
        cnt = cnt + jnp.sum(ms)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask=None):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
