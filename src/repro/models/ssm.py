"""Mamba2 (SSD — state-space duality) mixer.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic form +
inter-chunk recurrence); decode is the O(1)-per-token recurrent update that
makes SSM decode the most bandwidth-bound workload in the zoo (the Harli
harvesting margin is largest here).

The intra-chunk quadratic form is the compute hot-spot — a Pallas kernel in
kernels/ssd_scan.py implements it; `ssd_chunked` below is the jnp reference
(and CPU path).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


def ssm_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    dinner, ds, nh = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_nheads
    convdim = dinner + 2 * ds
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        # in_proj -> [z, x, B, C, dt]
        "in_proj": (jax.random.normal(ks[0], (d, 2 * dinner + 2 * ds + nh)) * s
                    ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, convdim))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((convdim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.ones((dinner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (dinner, d)) * dinner ** -0.5
                     ).astype(dtype),
    }


def make_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Dict:
    dinner, ds, nh, hd = (cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_nheads,
                          cfg.ssm_headdim)
    return {
        "h": jnp.zeros((batch, nh, hd, ds), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, dinner + 2 * ds),
                          jnp.bfloat16),
    }


def _split_proj(p, x, cfg):
    dinner, ds, nh = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_nheads
    zxbcdt = jnp.einsum("...d,do->...o", x, p["in_proj"].astype(x.dtype))
    z = zxbcdt[..., :dinner]
    xbc = zxbcdt[..., dinner:dinner + dinner + 2 * ds]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt


def ssm_prefill(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                state: Optional[Dict] = None,
                use_kernel: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, d). Returns (y, final_state)."""
    B, S, d = x.shape
    dinner, ds, nh, hd = (cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_nheads,
                          cfg.ssm_headdim)
    z, xbc, dt = _split_proj(p, x, cfg)

    # causal depthwise conv1d, width w
    w = cfg.ssm_conv_width
    pad = jnp.zeros((B, w - 1, xbc.shape[-1]), xbc.dtype) if state is None \
        else state["conv"].astype(xbc.dtype)
    xbc_p = jnp.concatenate([pad, xbc], axis=1)
    conv = sum(xbc_p[:, i:i + S] * p["conv_w"][i].astype(xbc.dtype)
               for i in range(w)) + p["conv_b"].astype(xbc.dtype)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs = conv[..., :dinner].reshape(B, S, nh, hd)
    Bt = conv[..., dinner:dinner + ds]
    Ct = conv[..., dinner + ds:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                          # (nh,)
    h0 = None if state is None else state["h"]
    if use_kernel:
        from repro.kernels import ops as kops
        y, hT = kops.ssd_scan(xs, dt, A, Bt, Ct, cfg.ssm_chunk, h0=h0)
    else:
        y, hT = ssd_chunked(xs, dt, A, Bt, Ct, cfg.ssm_chunk, h0=h0)
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(B, S, dinner).astype(x.dtype)

    # gated RMSNorm then out-projection
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                   p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,do->bso", y, p["out_proj"].astype(x.dtype))
    new_state = None
    if state is not None:
        new_state = {"h": hT,
                     "conv": xbc_p[:, S:].astype(state["conv"].dtype)}
    return out, new_state


def ssd_chunked(xs, dt, A, Bt, Ct, chunk: int, h0=None):
    """Reference chunked SSD.

    xs: (B,S,nh,hd) dt: (B,S,nh) A: (nh,) Bt/Ct: (B,S,ds)
    Returns y: (B,S,nh,hd) float32, hT: (B,nh,hd,ds) float32.
    """
    B, S, nh, hd = xs.shape
    ds = Bt.shape[-1]
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, pad), (0, 0)))
    xs = xs.reshape(B, n, c, nh, hd).astype(jnp.float32)
    dt = dt.reshape(B, n, c, nh)
    Bt = Bt.reshape(B, n, c, ds).astype(jnp.float32)
    Ct = Ct.reshape(B, n, c, ds).astype(jnp.float32)

    la = dt * A[None, None, None, :]              # log decay per step (B,n,c,nh)
    cum = jnp.cumsum(la, axis=2)                  # inclusive cumsum

    # intra-chunk quadratic form: y[i] = sum_{j<=i} C_i.B_j exp(cum_i-cum_j) dt_j x_j
    scores = jnp.einsum("bncs,bnms->bncm", Ct, Bt)             # (B,n,c,c)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,n,c,c,nh)
    causal = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, -jnp.inf)
    M = scores[..., None] * jnp.exp(decay)                     # (B,n,c,c,nh)
    M = jnp.where(causal[None, None, :, :, None], M, 0.0)
    y_intra = jnp.einsum("bncmh,bnmh,bnmhp->bnchp", M, dt, xs)

    # chunk-final states: h_n = sum_j exp(cum_end - cum_j) dt_j x_j B_j^T
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)                 # (B,n,c,nh)
    hc = jnp.einsum("bnch,bnch,bnchp,bncs->bnhps", dec_end, dt, xs, Bt)

    # inter-chunk recurrence over n chunks
    a_chunk = jnp.exp(cum[:, :, -1, :])                        # (B,n,nh)
    h_init = (jnp.zeros((B, nh, hd, ds), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def step(h, inp):
        a, hcn, Cn, cumn = inp
        y_in = jnp.einsum("bcs,bhps,bch->bchp", Cn, h, jnp.exp(cumn))
        h_new = a[:, :, None, None] * h + hcn
        return h_new, y_in

    xs_scan = (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(hc, 1, 0),
               jnp.moveaxis(Ct, 1, 0), jnp.moveaxis(cum, 1, 0))
    hT, y_inter = jax.lax.scan(step, h_init, xs_scan)
    y_inter = jnp.moveaxis(y_inter, 0, 1)                      # (B,n,c,nh,hd)
    y = (y_intra + y_inter).reshape(B, n * c, nh, hd)
    # padding contributes dt=0 (no state update, decay 1) so hT is exact
    return y[:, :S], hT


def ssm_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig
               ) -> Tuple[jax.Array, Dict]:
    """One-token recurrent update. x: (B, 1, d)."""
    B = x.shape[0]
    dinner, ds, nh, hd = (cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_nheads,
                          cfg.ssm_headdim)
    z, xbc, dt = _split_proj(p, x[:, 0], cfg)

    conv_buf = jnp.concatenate(
        [state["conv"].astype(xbc.dtype), xbc[:, None]], axis=1)  # (B, w, cd)
    conv = jnp.einsum("bwc,wc->bc", conv_buf, p["conv_w"].astype(xbc.dtype))
    conv = conv + p["conv_b"].astype(xbc.dtype)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xsv = conv[..., :dinner].reshape(B, nh, hd).astype(jnp.float32)
    Btv = conv[..., dinner:dinner + ds].astype(jnp.float32)
    Ctv = conv[..., dinner + ds:].astype(jnp.float32)

    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, nh)
    a = jnp.exp(dtv * (-jnp.exp(p["A_log"])))                      # (B, nh)
    h = state["h"]
    h = a[:, :, None, None] * h + jnp.einsum(
        "bh,bhp,bs->bhps", dtv, xsv, Btv)
    y = jnp.einsum("bs,bhps->bhp", Ctv, h) + xsv * p["D"][None, :, None]
    y = y.reshape(B, dinner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                   p["gate_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(x.dtype))[:, None]
    return out, {"h": h, "conv": conv_buf[:, 1:].astype(state["conv"].dtype)}
