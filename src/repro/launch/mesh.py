"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return Mesh(np.asarray(devices[:need]).reshape(shape), axes)


def make_debug_mesh(data: int = 2, model: int = 4) -> Mesh:
    """Small mesh for tests (needs device_count >= data*model)."""
    devices = jax.devices()
    need = data * model
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:need]).reshape(data, model),
                ("data", "model"))
