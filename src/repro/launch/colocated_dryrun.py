import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Colocated-step dry-run: the paper's signature artifact at full scale.

One XLA program fusing a llama3-8b decode round (bs=128, 32k KV cache) with
k qwen2.5-7b LoRA layer-units — lowered and compiled for the production
mesh. This is the program the Harli scheduler dispatches per decode round
(core/colocation.py); compiling it at paper scale proves the co-location
technique itself is mesh-coherent, beyond the per-phase cells.

  python -m repro.launch.colocated_dryrun [--k 4] [--mesh single]
Results: dryrun_results/colocated__<inf>__<ft>__k<k>__<mesh>.json
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import partitioning as PT
from repro.distributed.sharding import use_mesh
from repro.launch import hlo_analysis as HA
from repro.launch import specs as SP
from repro.launch.dryrun import RESULTS_DIR, to_named
from repro.launch.mesh import make_production_mesh
from repro.models import model as MD
from repro.training import peft as PF
from repro.training.data import DataConfig, Prefetcher, SyntheticCorpus


def run(inf_arch: str, ft_arch: str, k: int, mesh_kind: str,
        bs: int = 128, s_max: int = 32768):
    cfg_inf = get_config(inf_arch)
    cfg_ft = get_config(ft_arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    pc = PF.PeftConfig(micro_batch=2, seq_len=1024, accum=8)

    # --- input structs (no allocation except the tiny staged data ring) --
    params_inf = SP.param_structs(cfg_inf)
    params_ft = SP.param_structs(cfg_ft)
    tokens = jax.ShapeDtypeStruct((bs,), jnp.int32)
    positions = jax.ShapeDtypeStruct((bs,), jnp.int32)
    cache = jax.eval_shape(lambda: MD.init_cache(cfg_inf, bs, s_max))
    pf = Prefetcher(SyntheticCorpus(DataConfig(
        cfg_ft.vocab_size, pc.seq_len, pc.micro_batch)).batches(),
        pc.n_stage)
    staged = pf.stacked()
    ft_state = jax.eval_shape(
        lambda: PF.init_ft_state(cfg_ft, pc, None, jax.random.PRNGKey(0),
                                 staged))

    def step(p_inf, p_ft, tok, pos, cache, ft):
        logits, cache = MD.decode_step(p_inf, cfg_inf, tok, pos, cache)
        unit_step = PF.make_unit_step(cfg_ft, pc, p_ft)
        ft = PF.run_units(unit_step, ft, k)
        return logits, cache, ft

    axes = PT.MeshAxes()
    tokspec = P(PT._fit(mesh, bs, axes.present(mesh).dp))
    shardings = (
        PT.param_specs(cfg_inf, params_inf, mesh, axes),
        PT.param_specs(cfg_ft, params_ft, mesh, axes),
        tokspec, tokspec,
        PT.cache_specs(cfg_inf, cache, mesh, axes),
        jax.tree.map(lambda _: P(), ft_state),   # ft state is tiny: replicate
    )
    t0 = time.time()
    with use_mesh(mesh):
        jitted = jax.jit(step, in_shardings=to_named(shardings, mesh),
                         donate_argnums=(4, 5))
        lowered = jitted.lower(params_inf, params_ft, tokens, positions,
                               cache, ft_state)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    stats = HA.analyze(hlo)
    upcast = HA.cpu_bf16_upcast_bytes(hlo)
    resident = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "kind": "colocated", "inf": inf_arch, "ft": ft_arch, "k": k,
        "mesh": mesh_kind, "chips": int(mesh.devices.size),
        "bs": bs, "s_max": s_max, "ok": True,
        "compile_s": round(time.time() - t0, 2),
        "memory": {
            "argument_size_in_bytes": int(mem.argument_size_in_bytes),
            "output_size_in_bytes": int(mem.output_size_in_bytes),
            "temp_size_in_bytes": int(mem.temp_size_in_bytes),
            "alias_size_in_bytes": int(mem.alias_size_in_bytes),
            "cpu_bf16_upcast_bytes": int(upcast),
            "resident_bytes": int(resident),
            "resident_tpu_bytes": int(max(
                resident - upcast,
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                - mem.alias_size_in_bytes)),
        },
        "hlo": stats.as_dict(),
    }
    RESULTS_DIR.mkdir(exist_ok=True)
    out = RESULTS_DIR / \
        f"colocated__{inf_arch}__{ft_arch}__k{k}__{mesh_kind}.json"
    out.write_text(json.dumps(rec, indent=1))
    print(f"[ok] colocated {inf_arch}+{ft_arch} k={k} {mesh_kind} "
          f"({rec['compile_s']}s) resident_tpu="
          f"{rec['memory']['resident_tpu_bytes']/2**30:.1f} GiB "
          f"coll={stats.collective_total_tpu/1e9:.2f} GB/step")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--inf", default="llama3-8b")
    ap.add_argument("--ft", default="qwen2.5-7b")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    a = ap.parse_args()
    run(a.inf, a.ft, a.k, a.mesh)


if __name__ == "__main__":
    main()
