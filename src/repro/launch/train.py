"""PEFT finetune driver.

Runs real training on CPU with reduced (smoke) configs, or lowers the full
config for the production mesh (--dryrun goes through launch/dryrun.py
instead). Demonstrates checkpoint/restart fault tolerance end to end.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.distributed.fault_tolerance import CheckpointManager
from repro.models import model as MD
from repro.training import peft as P
from repro.training.data import DataConfig, Prefetcher, SyntheticCorpus
from repro.training.optimizer import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--layer-units", action="store_true",
                    help="run via the layer-unit engine instead of the "
                         "one-shot train step")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = MD.init_params(cfg, key)
    adapters = MD.init_adapters(cfg, jax.random.PRNGKey(1))
    opt_cfg = AdamWConfig(lr=args.lr)
    opt = adamw_init(adapters)

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        frontend_tokens=cfg.frontend_tokens if cfg.frontend == "vision" else 0,
        enc_frames=args.seq // 2 if cfg.enc_layers else 0,
        d_model=cfg.d_model)
    data = SyntheticCorpus(dcfg).batches()

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore({"adapters": adapters, "opt": opt})
        adapters = jax.tree.map(jnp.asarray, state["adapters"])
        opt = jax.tree.map(jnp.asarray, state["opt"])
        start = ckpt.latest_step()
        print(f"resumed from step {start}")

    if args.layer_units:
        pc = P.PeftConfig(micro_batch=args.batch, seq_len=args.seq, accum=1,
                          opt=opt_cfg)
        pf = Prefetcher(data, depth=pc.n_stage)
        state = P.init_ft_state(cfg, pc, params, jax.random.PRNGKey(1),
                                pf.stacked())
        unit = jax.jit(P.make_unit_step(cfg, pc, params))
        upi = P.units_per_iteration(cfg, pc.accum)
        for step in range(start, args.steps):
            t0 = time.time()
            for _ in range(upi):
                state = unit(state)
            consumed = int(state["consumed"])
            state["consumed"] = jnp.zeros((), jnp.int32)
            pf.refill(consumed)
            state["data"] = {k: jnp.asarray(v)
                             for k, v in pf.stacked().items()}
            print(f"step {step:4d} loss {float(state['last_loss']):.4f} "
                  f"({time.time() - t0:.2f}s, {upi} units)")
        return

    train_step = jax.jit(P.make_train_step(cfg, opt_cfg, remat=True))
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        adapters, opt, metrics = train_step(params, adapters, opt, batch)
        print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
              f"ce {float(metrics['ce']):.4f} ({time.time() - t0:.2f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"adapters": adapters, "opt": opt},
                      blocking=False)
    if ckpt:
        ckpt.save(args.steps, {"adapters": adapters, "opt": opt})
        ckpt.wait()
        print(f"checkpoints at {sorted(ckpt.steps())}")


if __name__ == "__main__":
    main()
