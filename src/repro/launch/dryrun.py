import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the production
mesh is built from 512 host placeholder devices (the two lines above MUST
precede any jax import), every cell's step function is jit'd with explicit
in_shardings, lowered, compiled, and its memory_analysis / cost_analysis /
collective schedule recorded to JSON (benchmarks/roofline.py reads these).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
Results: dryrun_results/<arch>__<shape>__<mesh>.json (incremental cache).
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import hw
from repro.configs import SHAPES, cells, get_config
from repro.distributed import partitioning as PT
from repro.distributed.sharding import use_mesh
from repro.launch import hlo_analysis as HA
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "dryrun_results"


def _analytic_activation_bytes(cfg, cell, mesh) -> int:
    """Per-device activation watermark on TPU (bf16 natively; no legalized
    f32 weight copies). Conservative: working-set terms use x4 headroom."""
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = names.get("data", 1) * names.get("pod", 1)
    tp = names.get("model", 1)
    B, S, d = cell.global_batch, cell.seq_len, cfg.d_model
    V = cfg.vocab_size
    from repro.models.model import _plan
    _, _, n_scan, _ = _plan(cfg)
    dp_eff = dp if B % dp == 0 else 1
    sp_eff = tp if S % tp == 0 else 1
    tok_sp = B * S / dp_eff / sp_eff       # fully sharded token count
    tok_dp = B * S / dp_eff                # dp-sharded only
    if cell.kind == "decode":
        # one-token round: scores + per-layer workset (cache is in args)
        ctx = cfg.effective_cache_len(S)
        scores = (B / dp_eff) * cfg.num_heads * (ctx / sp_eff) * 4
        return int(4 * scores + 8 * (B / dp_eff) * d * 4 + 2 ** 28)
    work = 4 * tok_sp * d * 2 * 4          # per-layer transient (x4 slack)
    if cfg.moe:
        cf = cfg.capacity_factor
        xe = cfg.top_k * cf * tok_dp / tp * (d + cfg.moe_d_ff) * 2
        work += 3 * xe
    if cell.kind == "prefill":
        return int(work + 2 ** 28)
    # train: remat carries + flash bwd accumulators + CE logits
    carries = (n_scan + 1) * tok_sp * d * 2
    flash = 2 * (B / dp_eff) * cfg.effective_cache_len(S) \
        * cfg.num_kv_heads * cfg.head_dim * 4
    vshard = tp if V % tp == 0 else 1
    # CE is fused+chunked (layers.chunked_softmax_xent): per-chunk logits
    ce = 2 * (B / dp_eff) * 256 * (V / vshard) * 4
    return int(carries + 2 * work + flash + ce + 2 ** 28)


# -------------------------------------------------------------- shardings --
def pick_strategy(cfg, cell, mesh) -> str:
    """Per-cell sharding strategy (§Perf cells C/D): LoRA train steps whose
    global batch covers the whole mesh go pure-FSDP (no per-layer activation
    collectives). MoE archs join when the per-layer weight gather is
    affordable (mixtral: 2.8 GB/layer -> FSDP wins ~5x; deepseek-v3:
    22.5 GB/layer -> EP stays the right call)."""
    n_dev = int(mesh.devices.size)
    if cell.kind == "train" and cell.global_batch % n_dev == 0:
        layer_bytes = cfg.param_count() / max(cfg.num_layers, 1) * 2.0
        if not cfg.moe or layer_bytes < 4e9:
            return "fsdp"
    return "tp"


def arg_shardings(cfg, cell_kind, args, mesh, strategy: str = "tp"):
    """in_shardings tree matching make_cell_fn's arg order."""
    axes = PT.MeshAxes()
    if cell_kind == "train":
        params, adapters, opt, batch = args
        if strategy == "fsdp":
            fs_batch = _walk_batch_fsdp(batch, mesh)
            return (
                PT.fsdp_param_specs(cfg, params, mesh),
                PT.adapter_specs(cfg, adapters, mesh, axes),
                jax.tree.map(lambda _: P(), opt),
                fs_batch,
            )
        return (
            PT.param_specs(cfg, params, mesh, axes),
            PT.adapter_specs(cfg, adapters, mesh, axes),
            jax.tree.map(lambda _: P(), opt),
            PT.batch_specs(batch, mesh, axes),
        )
    if cell_kind == "prefill":
        params, batch, cache = args
        return (
            PT.param_specs(cfg, params, mesh, axes),
            PT.batch_specs(batch, mesh, axes),
            PT.cache_specs(cfg, cache, mesh, axes),
        )
    params, tokens, positions, cache = args
    ax = axes.present(mesh)
    tokspec = P(PT._fit(mesh, tokens.shape[0], ax.dp))
    return (
        PT.param_specs(cfg, params, mesh, axes),
        tokspec, tokspec,
        PT.cache_specs(cfg, cache, mesh, axes),
    )


def _walk_batch_fsdp(batch, mesh):
    axes = ("pod", "data", "model")
    present = tuple(a for a in axes if a in mesh.axis_names)

    def spec(path, leaf):
        dims = [None] * leaf.ndim
        if leaf.ndim >= 1 and leaf.shape[0] % PT._axis_size(
                mesh, present) == 0:
            dims[0] = present
        return P(*dims)

    return PT._walk(batch, spec)


def to_named(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- one cell --
def run_cell(arch: str, shape: str, mesh_kind: str, force: bool = False,
             kv_quant: bool = False):
    import dataclasses as _dc
    RESULTS_DIR.mkdir(exist_ok=True)
    suffix = "__kvq" if kv_quant else ""
    out_path = RESULTS_DIR / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
    if out_path.exists() and not force:
        print(f"[skip] {out_path.name} (cached)")
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if kv_quant:
        cfg = _dc.replace(cfg, kv_quant=True)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "chips": int(n_chips), "kind": cell.kind,
           "seq_len": cell.seq_len, "global_batch": cell.global_batch}
    try:
        step, args = SP.make_cell_fn(cfg, cell)
        strategy = pick_strategy(cfg, cell, mesh)
        rec["strategy"] = strategy
        shardings = arg_shardings(cfg, cell.kind, args, mesh, strategy)
        # donation: the decode/prefill cache and the train adapter/optimizer
        # states are updated in place (aliased buffers) — without it every
        # step would hold two copies of the KV cache
        donate = {"train": (1, 2), "prefill": (2,), "decode": (3,)}[cell.kind]
        from repro.distributed.sharding import FSDP_RULES
        rules = FSDP_RULES if strategy == "fsdp" else None
        with use_mesh(mesh, rules=rules):
            jitted = jax.jit(step, in_shardings=to_named(shardings, mesh),
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        stats = HA.analyze(hlo)

        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            # per-device buffer sizes (proves HBM fit)
            "memory": {
                k: int(getattr(mem, k, 0) or 0)
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")
            },
            # raw cost_analysis (counts while bodies once — kept for
            # reference; the roofline uses the trip-corrected hlo stats)
            "cost_raw": {k: float(v) for k, v in cost.items()
                         if isinstance(v, (int, float)) and
                         k in ("flops", "bytes accessed", "transcendentals")},
            # trip-count-corrected per-device analysis
            "hlo": stats.as_dict(),
            "hlo_bytes": len(hlo),
        })
        mm = rec["memory"]
        resident = (mm["argument_size_in_bytes"]
                    + mm["output_size_in_bytes"]
                    + mm["temp_size_in_bytes"]
                    - mm["alias_size_in_bytes"])
        # CPU-backend artifact: bf16 dots are legalized via hoisted f32
        # weight copies that do not exist on TPU (native bf16 MXU). The
        # instruction-level estimate can over/under-count vs the liveness-
        # aware buffer assignment, so an analytic activation watermark is
        # recorded as the primary TPU figure (EXPERIMENTS.md §Dry-run).
        upcast = HA.cpu_bf16_upcast_bytes(hlo)
        weights_cache = (mm["argument_size_in_bytes"]
                         + mm["output_size_in_bytes"]
                         - mm["alias_size_in_bytes"])
        act = _analytic_activation_bytes(cfg, cell, mesh)
        rec["memory"]["cpu_bf16_upcast_bytes"] = int(upcast)
        rec["memory"]["resident_bytes"] = int(resident)
        rec["memory"]["resident_tpu_bytes"] = int(
            max(resident - upcast, weights_cache))
        rec["memory"]["analytic_activation_bytes"] = int(act)
        rec["memory"]["resident_analytic_bytes"] = int(weights_cache + act)
        # analytic workload for the MODEL_FLOPS/HLO_FLOPS ratio
        n_total = cfg.param_count()
        n_active = cfg.active_param_count()
        tokens = cell.global_batch * cell.seq_len
        if cfg.enc_layers:
            # enc-dec: seq splits enc/dec halves; the (frozen) encoder is
            # forward-only in PEFT training
            d, ff = cfg.d_model, cfg.d_ff
            per_attn = 4 * d * cfg.num_heads * cfg.head_dim
            n_enc = cfg.enc_layers * (per_attn + 3 * d * ff + 2 * d)
            n_dec = n_active - n_enc
            if cell.kind == "train":
                rec["model_flops"] = (6.0 * n_dec + 2.0 * n_enc) * tokens / 2
            elif cell.kind == "prefill":
                rec["model_flops"] = 2.0 * n_active * tokens / 2
            else:
                rec["model_flops"] = 2.0 * n_dec * cell.global_batch
        elif cell.kind == "train":
            rec["model_flops"] = 6.0 * n_active * tokens
        elif cell.kind == "prefill":
            rec["model_flops"] = 2.0 * n_active * tokens
        else:
            rec["model_flops"] = 2.0 * n_active * cell.global_batch
        rec["params_total"] = n_total
        rec["params_active"] = n_active
    except Exception as e:  # record failures — they are bugs to fix
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    rec["wall_s"] = round(time.time() - t0, 2)
    out_path.write_text(json.dumps(rec, indent=1))
    status = "ok" if rec.get("ok") else "FAIL"
    print(f"[{status}] {arch} x {shape} x {mesh_kind} "
          f"({rec['wall_s']}s)" + ("" if rec.get("ok") else
                                   f"\n  {rec['error']}"))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache variant (writes __kvq.json)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    ok = fail = 0
    if args.all:
        for arch, shape, skip in cells(include_skipped=True):
            if skip:
                print(f"[SKIP-CELL] {arch} x {shape}: {skip}")
                continue
            for mk in meshes:
                rec = run_cell(arch, shape, mk, args.force)
                ok += bool(rec.get("ok"))
                fail += not rec.get("ok")
    else:
        for mk in meshes:
            rec = run_cell(args.arch, args.shape, mk, args.force,
                           kv_quant=args.kv_quant)
            ok += bool(rec.get("ok"))
            fail += not rec.get("ok")
    print(f"done: {ok} ok, {fail} failed")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
