"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No allocation anywhere: params/adapters/optimizer come from jax.eval_shape
over the real initializers; batches/caches are constructed as structs.
Modality frontends are STUBS per the assignment: ``vlm`` cells get
precomputed patch embeddings, ``audio`` cells get precomputed frame
embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell
from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.training import peft as P
from repro.training.optimizer import AdamWConfig, adamw_init

Struct = jax.ShapeDtypeStruct

AUDIO_DECODE_ENC_LEN = 2048   # cross-attention source length for decode cells


def _sds(tree):
    return jax.tree.map(lambda x: Struct(x.shape, x.dtype), tree)


def param_structs(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: MD.init_params(cfg, jax.random.PRNGKey(0), dtype))


def adapter_structs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: MD.init_adapters(cfg, jax.random.PRNGKey(0)))


def opt_structs(adapters):
    return jax.eval_shape(lambda a: adamw_init(a), adapters)


def _seq_split(cfg: ModelConfig, seq_len: int) -> Tuple[int, int]:
    """(text_tokens, frontend_len) so total context == seq_len."""
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        return seq_len - cfg.frontend_tokens, cfg.frontend_tokens
    if cfg.enc_layers:                       # enc-dec: half frames, half text
        return seq_len // 2, seq_len // 2
    return seq_len, 0


def train_batch_structs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    B = cell.global_batch
    S_text, front = _seq_split(cfg, cell.seq_len)
    batch = {
        "tokens": Struct((B, S_text), jnp.int32),
        "labels": Struct((B, S_text), jnp.int32),
        "mask": Struct((B, S_text), jnp.float32),
    }
    if cfg.frontend == "vision" and front:
        batch["frontend"] = Struct((B, front, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers:
        batch["enc_frames"] = Struct((B, front, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_structs(cfg: ModelConfig, cell: ShapeCell):
    B = cell.global_batch
    S_text, front = _seq_split(cfg, cell.seq_len)
    batch = {"tokens": Struct((B, S_text), jnp.int32)}
    if cfg.frontend == "vision" and front:
        batch["frontend"] = Struct((B, front, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers:
        batch["enc_frames"] = Struct((B, front, cfg.d_model), jnp.bfloat16)
    enc_len = front if cfg.enc_layers else 0
    cache = jax.eval_shape(
        lambda: MD.init_cache(cfg, B, cell.seq_len, enc_len=enc_len))
    return batch, cache


def decode_structs(cfg: ModelConfig, cell: ShapeCell):
    B = cell.global_batch
    enc_len = AUDIO_DECODE_ENC_LEN if cfg.enc_layers else 0
    cache = jax.eval_shape(
        lambda: MD.init_cache(cfg, B, cell.seq_len, enc_len=enc_len))
    tokens = Struct((B,), jnp.int32)
    positions = Struct((B,), jnp.int32)
    return tokens, positions, cache


# ------------------------------------------------------------- step fns ---
def make_cell_fn(cfg: ModelConfig, cell: ShapeCell, use_kernels: bool = False
                 ) -> Tuple[Callable, Tuple[Any, ...]]:
    """Returns (step_fn, arg_structs) for a dry-run cell.

    train  -> PEFT train step (paper workload: LoRA finetune)
    prefill-> prompt processing into a fresh cache
    decode -> one serve_step token over a seq_len cache
    """
    if cell.kind == "train":
        step = P.make_train_step(cfg, AdamWConfig(), use_kernels=False,
                                 remat=True)
        params = param_structs(cfg)
        adapters = adapter_structs(cfg)
        opt = opt_structs(adapters)
        batch = train_batch_structs(cfg, cell)
        return step, (params, adapters, opt, batch)
    if cell.kind == "prefill":
        batch, cache = prefill_structs(cfg, cell)

        def step(params, batch, cache):
            return MD.prefill(params, cfg, batch, cache)

        return step, (param_structs(cfg), batch, cache)
    # decode
    tokens, positions, cache = decode_structs(cfg, cell)

    def step(params, tokens, positions, cache):
        return MD.decode_step(params, cfg, tokens, positions, cache)

    return step, (param_structs(cfg), tokens, positions, cache)
