"""Post-optimization HLO analysis for the roofline terms.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which under
scan-over-layers understates a 61-layer model by ~60x. This module parses
``compiled.as_text()`` instead:

  * builds the computation call graph (while bodies, fusion calls),
  * propagates loop trip counts (``known_trip_count`` backend configs) so an
    op inside a scanned layer counts num_layers times,
  * sums matmul FLOPs from ``dot`` ops (2 * prod(result dims) * K) — matmuls
    dominate every cell; elementwise FLOPs are ignored and this is recorded,
  * sums collective bytes (result sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute).

All numbers are PER DEVICE (the SPMD module is per-partition); the roofline
multiplies by chip count.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4, "s8": 1,
    "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# computation headers start at column 0: "%name (args...) -> type {" —
# args may contain nested tuple parens, so match only the name prefix
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-$]+)\s+\(")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-$]+)\s*=\s*(\([^)]*\)|\S+)\s+"
                  r"([\w\-$]+)\(")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_info(sig: str) -> Tuple[int, List[int]]:
    """(bytes, dims) of the FIRST array shape in a type signature."""
    m = _SHAPE.search(sig)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[m.group(1)], dims


def _tuple_bytes(sig: str) -> int:
    total = 0
    for dt, ds in _SHAPE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in ds.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class HloStats:
    dot_flops: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVES})
    collective_f32_bytes: float = 0.0   # CPU bf16-legalization inflated
    loop_trip_counts: List[int] = dataclasses.field(default_factory=list)

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def collective_total_tpu(self) -> float:
        """bf16-equivalent: f32 collectives carrying legalized-bf16 data
        move half the bytes on TPU."""
        return self.collective_total - self.collective_f32_bytes / 2

    def as_dict(self) -> Dict:
        return {
            "dot_flops": self.dot_flops,
            "collective_bytes": dict(self.collective_bytes,
                                     total=self.collective_total,
                                     total_tpu=self.collective_total_tpu),
            "collective_counts": self.collective_counts,
            "loop_trip_counts": self.loop_trip_counts,
        }


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def analyze(hlo: str) -> HloStats:
    comps = _split_computations(hlo)
    stats = HloStats()

    # --- call graph + loop multipliers --------------------------------
    callers: Dict[str, List[str]] = {}          # callee -> [caller]
    trip: Dict[str, int] = {}                   # while-body comp -> trips
    for name, lines in comps.items():
        for line in lines:
            for mm in re.finditer(r"(?:calls|body|condition|to_apply)"
                                  r"=%?([\w.\-$]+)", line):
                callers.setdefault(mm.group(1), []).append(name)
            wb = re.search(r"body=%?([\w.\-$]+)", line)
            if wb:
                tc = re.search(r'known_trip_count..?.?.?n.?.?.?"?(\d+)', line)
                if tc:
                    trip[wb.group(1)] = int(tc.group(1))
                    stats.loop_trip_counts.append(int(tc.group(1)))

    def eff_mult(comp: str, depth: int = 0) -> int:
        if depth > 16:
            return 1
        m = trip.get(comp, 1)
        cs = callers.get(comp, [])
        if not cs:
            return m
        return m * max(eff_mult(c, depth + 1) for c in cs)

    mults = {name: eff_mult(name) for name in comps}

    # --- per-computation op accounting ---------------------------------
    for name, lines in comps.items():
        k = mults.get(name, 1)
        shapes: Dict[str, List[int]] = {}
        for line in lines:
            d = _DEF.match(line)
            if not d:
                pm = re.match(r"\s*%?([\w.\-$]+)\s*=\s*(\S+)\s+parameter",
                              line)
                if pm:
                    _, dims = _shape_info(pm.group(2))
                    shapes[pm.group(1)] = dims
                continue
            var, sig, op = d.groups()
            _, dims = _shape_info(sig)
            shapes[var] = dims
            if op == "dot":
                flops = _dot_flops(line, sig, shapes)
                stats.dot_flops += flops * k
            elif op in COLLECTIVES or \
                    op.replace("-start", "") in COLLECTIVES:
                base = op.replace("-start", "")
                if base in COLLECTIVES:
                    b = _tuple_bytes(sig)
                    stats.collective_bytes[base] += b * k
                    stats.collective_counts[base] += 1
                    if "f32[" in sig:
                        stats.collective_f32_bytes += b * k
    return stats


def _dot_flops(line: str, result_sig: str, shapes: Dict[str, List[int]]
               ) -> float:
    """2 * prod(result dims) * prod(contracting dims)."""
    _, rdims = _shape_info(result_sig)
    n = 1
    for d in rdims:
        n *= d
    # operands may carry type annotations: dot(f32[8,16]{1,0} %lhs, ...);
    # dims may be bounded-dynamic (<=16), so match anything up to the ]
    ops = re.search(r"dot\(\s*(?:\w+\[[^\]]*\]\S*\s+)?%?([\w.\-$]+)", line)
    lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    kprod = 1
    if ops and lc and ops.group(1) in shapes:
        lshape = shapes[ops.group(1)]
        for idx in lc.group(1).split(","):
            if idx and int(idx) < len(lshape):
                kprod *= lshape[int(idx)]
    return 2.0 * n * kprod


def cpu_bf16_upcast_bytes(hlo: str) -> int:
    """Bytes of f32 buffers that exist only because the CPU backend
    legalizes bf16 dots by upcasting weights to f32 (hoisted out of layer
    loops). A real TPU executes those dots natively in bf16, so per-chip
    memory on hardware excludes these buffers.

    Heuristic: every distinct ``f32 convert(...)`` instruction >= 16MB whose
    leading dim equals a known loop trip count (a scanned weight stack) or
    whose dims match a bf16 entry-parameter shape. Counted once per
    variable (gate/up/down stacks share shapes but are separate buffers)."""
    trips = set()
    for m in re.finditer(r'known_trip_count..?.?.?n.?.?.?"?(\d+)', hlo):
        trips.add(int(m.group(1)))
    bf16_param_shapes = set()
    for m in re.finditer(r"=\s*bf16\[([0-9,]+)\]\S*\s+parameter", hlo):
        bf16_param_shapes.add(tuple(int(d) for d in m.group(1).split(",")))

    seen_vars = set()
    total = 0
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%([\w.\-$]+)\s*=\s*f32\[([0-9,]+)\]\S*"
                     r"\s+convert\(", line)
        if not m:
            continue
        var = m.group(1)
        dims = tuple(int(d) for d in m.group(2).split(","))
        n = 1
        for d in dims:
            n *= d
        if n * 4 < 16 * 1024 * 1024 or var in seen_vars:
            continue
        if dims[0] in trips or dims in bf16_param_shapes:
            seen_vars.add(var)
            total += n * 4
    return total
