"""Serving driver: decode instance, optionally co-located with PEFT (Harli).

Real compute on CPU with reduced configs; the paper-scale co-location
numbers come from benchmarks/ (cost-model simulator).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 12 --colocate
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.colocation import ColocatedRunner
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.predictor import TwoStageLatencyPredictor
from repro.core.scheduler import QoSScheduler, SchedulerConfig
from repro.models import model as MD
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.serving.trace import TraceConfig, generate
from repro.training import peft as P
from repro.training.data import DataConfig, Prefetcher, SyntheticCorpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--ft-arch", default="")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=160)
    ap.add_argument("--colocate", action="store_true")
    ap.add_argument("--k-max", type=int, default=6)
    ap.add_argument("--use-kernels", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_slots=args.slots, s_max=args.s_max,
                        enc_len=16 if cfg.enc_layers else 0,
                        use_kernels=args.use_kernels)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, arrival=i * 0.05,
                    prompt_len=int(rng.integers(8, 24)),
                    max_new_tokens=int(rng.integers(4, 12)))
            for i in range(args.requests)]

    runner = None
    sched = None
    ft_state = None
    if args.colocate:
        ft_name = args.ft_arch or args.arch
        cfg_ft = smoke_config(ft_name) if args.smoke else get_config(ft_name)
        params_ft = MD.init_params(cfg_ft, jax.random.PRNGKey(1))
        pc = P.PeftConfig(micro_batch=2, seq_len=32, accum=1)
        pf = Prefetcher(SyntheticCorpus(DataConfig(
            cfg_ft.vocab_size, 32, 2,
            enc_frames=16 if cfg_ft.enc_layers else 0,
            d_model=cfg_ft.d_model)).batches(), pc.n_stage)
        ft_state = P.init_ft_state(cfg_ft, pc, params_ft,
                                   jax.random.PRNGKey(2), pf.stacked())
        runner = ColocatedRunner(cfg, params, cfg_ft, params_ft, pc,
                                 k_max=args.k_max, donate=False)
        pred = TwoStageLatencyPredictor(k_max=args.k_max)
        pred.fit_from_costmodel(CostModel(get_config(args.arch),
                                          InstanceSpec(tp=2)))
        sched = QoSScheduler(pred, SchedulerConfig(k_max=args.k_max))

    t0 = time.time()
    pending = sorted(reqs, key=lambda r: r.arrival)
    qi = 0
    rounds = 0
    units_done = 0
    while rounds < 3000:
        while qi < len(pending):
            r = pending[qi]
            toks = rng.integers(0, cfg.vocab_size, size=r.prompt_len,
                                dtype=np.int32)
            if eng.try_admit(r, toks, eng._stub_extras(r)):
                qi += 1
            else:
                break
        active = eng.active_requests()
        if not active and qi >= len(pending):
            break
        if runner is not None and active:
            bs = len(active)
            ctx = sum(r.context_len for r in active) / bs
            k = sched.pick(bs, ctx, ft_ready=True,
                           ft_units_available=args.k_max).k
            tokens = jnp.asarray(eng.last_token)
            positions = np.zeros((eng.max_slots,), np.int32)
            for i, r in enumerate(eng.slots):
                if r is not None:
                    positions[i] = r.context_len
            logits, eng.cache, ft_state = runner.run_round(
                k, tokens, jnp.asarray(positions), eng.cache, ft_state)
            units_done += k
            nt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            for i, r in enumerate(eng.slots):
                if r is None:
                    continue
                eng.pages.extend(r.slot, 1)
                eng.last_token[i] = nt[i]
                r.generated += 1
                eng.metrics.tokens_out += 1
                if r.generated >= r.max_new_tokens:
                    from repro.serving.request import Phase
                    r.phase = Phase.DONE
                    eng.pages.release(r.slot)
                    eng.slots[i] = None
            eng.metrics.decode_rounds += 1
        else:
            eng.decode_round()
        rounds += 1

    m = eng.metrics
    print(f"arch={cfg.name} rounds={m.decode_rounds} tokens={m.tokens_out} "
          f"prefills={m.prefills} wall={time.time() - t0:.1f}s")
    if runner is not None:
        print(f"colocated finetune units executed: {units_done} "
              f"(ft loss so far: {float(ft_state['loss']):.4f})")


if __name__ == "__main__":
    main()
