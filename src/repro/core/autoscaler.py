"""Periodic fleet controller: decode-pool sizing + instance role flips.

Runs every ``interval_s`` of simulated time over a snapshot of per-instance
signals and emits at most one action per tick ("Taming the Chaos"-style
coordinated scaling: small reversible steps with a cooldown, never a bulk
reconfiguration). Actions:

  * ``add_instance``      — fleet saturated (high load or QoS violations
                            with no colocation left to shed)
  * ``remove_instance``   — sustained low load; the chosen instance drains
  * ``to_decode(id)``     — QoS under pressure: pause that instance's
                            finetune job (cheapest headroom first, §2.3 —
                            inference always preempts finetune)
  * ``to_colocated(id)``  — QoS headroom back + finetune backlog: resume
  * ``to_finetune(id)``   — deep idle + large backlog: dedicate an idle
                            instance to finetune until load returns
  * ``none``

A second, independent control loop (``evaluate_prefill``) sizes the
disaggregated prefill pool (core/prefill_pool.py); its chunked-mode
variant (``evaluate_chunked``) tunes the fleet-wide per-round chunk budget
instead (``grow_chunk_budget`` / ``shrink_chunk_budget``) — which loop
runs is the prefill placement's call (core/policies/placement.py).

This class is **mechanism only**: cooldown bookkeeping and the decision
log. The decisions themselves are ``ScalingPolicy`` classes resolved by
name through the control-plane registry (core/api.py; built-ins in
core/policies/scaling.py) — ``AutoscalerConfig.decode_policy`` /
``prefill_policy`` / ``chunk_policy`` select them, so a new scaling
strategy (model-predictive, deadline-aware, ...) is a registered plugin,
not an edit here. The controller never touches instances itself; the
cluster event loop (core/cluster.py) applies decisions. That keeps the
invariants testable — e.g. the built-in decode policy can never emit
``remove_instance`` or ``to_finetune`` when doing so would leave fewer
than ``min_decode`` serving instances.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core import api

ACTIONS = ("none", "add_instance", "remove_instance",
           "to_decode", "to_colocated", "to_finetune",
           "add_prefill", "remove_prefill",
           "grow_chunk_budget", "shrink_chunk_budget")


@dataclasses.dataclass
class AutoscalerConfig:
    interval_s: float = 5.0
    min_decode: int = 1              # serving instances, hard floor
    max_decode: int = 16
    scale_up_load: float = 0.85      # mean serving load above -> grow
    scale_down_load: float = 0.25    # mean serving load below -> shrink
    viol_frac_shed: float = 0.02     # QoS violations above -> shed finetune
    viol_frac_resume: float = 0.005  # below (and backlog) -> resume
    idle_load_ft: float = 0.05       # below (and backlog) -> dedicate to ft
    ft_target_iters_per_s: float = 0.0   # finetune demand; 0 = best-effort
    cooldown_ticks: int = 2          # ticks to wait after any action
    # ---- prefill-pool loop (coordinated with the decode loop: the pool
    # floor tracks the serving fleet so the two tiers move together,
    # ByteDance arXiv 2508.19559-style joint scaling against SLO headroom)
    min_prefill: int = 1             # pool hard floor
    max_prefill: int = 16
    prefill_per_decode: float = 1.0  # coordinated floor: ceil(r * serving)
    prefill_queue_hi: float = 2.0    # queued per worker above -> grow
    ttft_headroom: float = 0.6       # wait_p99 above frac*TTFT-SLO -> grow
    prefill_idle_backlog_s: float = 0.05  # backlog below + empty -> shrink
    prefill_cooldown_ticks: int = 0
    # ---- chunked-mode prefill loop (prefill_mode="chunked"): there is no
    # pool to size, so the same control slot tunes the per-round chunk
    # budget instead — grow when TTFT headroom erodes, give the tokens
    # back to decode/finetune when TTFT is comfortable but TPOT is not
    chunk_step_tokens: int = 64      # budget delta per action
    # ---- registered ScalingPolicy names, one per control loop
    decode_policy: str = "decode_fleet"
    prefill_policy: str = "pooled_prefill"
    chunk_policy: str = "chunked_budget"


@dataclasses.dataclass(frozen=True)
class InstanceSnapshot:
    inst_id: int
    role: str                        # decode | colocated | finetune
    load: float                      # queue+active over slot budget
    active: int                      # in-flight decode requests
    colocatable: bool                # has a finetune job attached
    can_serve: bool = True           # holds inference weights
    draining: bool = False


@dataclasses.dataclass
class ScaleDecision:
    t: float
    action: str                      # one of ACTIONS
    target: int = -1                 # instance id for role flips / removal
    reason: str = ""


class Autoscaler:
    def __init__(self, cfg: AutoscalerConfig = AutoscalerConfig()):
        self.cfg = cfg
        self.decisions: List[ScaleDecision] = []
        self._cooldown = 0
        self._prefill_cooldown = 0
        self._policies: Dict[str, api.ScalingPolicy] = {}
        self.prefill_ttft_slo_s = 4.0   # set by the cluster (RouterConfig)

    def _policy(self, name: str) -> api.ScalingPolicy:
        inst = self._policies.get(name)
        if inst is None:
            inst = self._policies[name] = \
                api.resolve_policy("scaling", name)()
        return inst

    # ------------------------------------------------------- decode loop --
    def evaluate(self, t: float, snaps: List[InstanceSnapshot],
                 viol_frac: float, ft_backlog: float = 0.0) -> ScaleDecision:
        """One decode-loop control tick: delegate to the configured
        ``decode_policy``, apply cooldown, record the decision."""
        if self._cooldown > 0:
            self._cooldown -= 1
            d = ScaleDecision(t, "none", reason="cooldown")
        else:
            d = self._policy(self.cfg.decode_policy).decide(
                t, self.cfg, dict(snaps=snaps, viol_frac=viol_frac,
                                  ft_backlog=ft_backlog))
            if d.action != "none":
                self._cooldown = self.cfg.cooldown_ticks
        assert d.action in ACTIONS
        self.decisions.append(d)
        return d

    # -------------------------------------------------- prefill-pool loop --
    def prefill_floor(self, n_serving: int) -> int:
        """Coordinated pool floor: the prefill tier tracks the decode tier
        (``prefill_per_decode`` workers per serving instance) so a decode
        scale-up pulls prefill capacity with it instead of waiting for the
        queue to back up first."""
        from repro.core.policies.scaling import coordinated_prefill_floor
        return coordinated_prefill_floor(self.cfg, n_serving)

    def evaluate_prefill(self, t: float, snap, n_serving: int
                         ) -> ScaleDecision:
        """One prefill-pool control tick (second loop), delegating to the
        configured ``prefill_policy``. Own cooldown so a decode action
        never starves the pool of attention; decisions land in the same
        log as the decode loop's. ``snap`` is a PrefillPoolSnapshot."""
        return self._prefill_tick(
            t, self.cfg.prefill_policy,
            dict(snap=snap, n_serving=n_serving,
                 ttft_slo_s=self.prefill_ttft_slo_s))

    def evaluate_chunked(self, t: float, wait_p99: float, viol_frac: float,
                         budget: int, lo: int, hi: int, n_serving: int = 0
                         ) -> ScaleDecision:
        """Chunked-mode variant of the prefill control loop: no pool to
        size, so it tunes the fleet-wide per-round chunk budget against
        TTFT headroom (``target`` on the decision carries the new budget),
        escalating to ``add_instance`` once the budget is maxed. Shares
        the prefill loop's cooldown — it occupies the same control slot,
        just mode-aware."""
        return self._prefill_tick(
            t, self.cfg.chunk_policy,
            dict(wait_p99=wait_p99, viol_frac=viol_frac, budget=budget,
                 lo=lo, hi=hi, n_serving=n_serving,
                 ttft_slo_s=self.prefill_ttft_slo_s))

    def _prefill_tick(self, t: float, policy: str,
                      signals: Dict) -> ScaleDecision:
        if self._prefill_cooldown > 0:
            self._prefill_cooldown -= 1
            d = ScaleDecision(t, "none", reason="prefill cooldown")
        else:
            d = self._policy(policy).decide(t, self.cfg, signals)
            if d.action != "none":
                self._prefill_cooldown = self.cfg.prefill_cooldown_ticks
        assert d.action in ACTIONS
        self.decisions.append(d)
        return d
