"""Periodic fleet controller: decode-pool sizing + instance role flips.

Runs every ``interval_s`` of simulated time over a snapshot of per-instance
signals and emits at most one action per tick ("Taming the Chaos"-style
coordinated scaling: small reversible steps with a cooldown, never a bulk
reconfiguration). Actions:

  * ``add_instance``      — fleet saturated (high load or QoS violations
                            with no colocation left to shed)
  * ``remove_instance``   — sustained low load; the chosen instance drains
  * ``to_decode(id)``     — QoS under pressure: pause that instance's
                            finetune job (cheapest headroom first, §2.3 —
                            inference always preempts finetune)
  * ``to_colocated(id)``  — QoS headroom back + finetune backlog: resume
  * ``to_finetune(id)``   — deep idle + large backlog: dedicate an idle
                            instance to finetune until load returns
  * ``none``

A second, independent control loop (``evaluate_prefill``) sizes the
disaggregated prefill pool (core/prefill_pool.py): grow on TTFT headroom
loss or queue depth, shrink on deep idle, and never below a floor that is
*coordinated* with the decode loop — ``prefill_per_decode`` workers per
serving instance — so the two tiers move together when the fleet scales.
Actions: ``add_prefill`` / ``remove_prefill``, logged in the same decision
stream. The loop is *mode-aware*: in chunked deployments
(prefill_mode="chunked", core/cluster.py) there is no pool to size, so the
same control slot runs ``evaluate_chunked`` instead and tunes the fleet's
per-round prefill chunk budget against TTFT headroom
(``grow_chunk_budget`` / ``shrink_chunk_budget``).

The controller is pure policy: it never touches instances itself, the
cluster event loop (core/cluster.py) applies decisions. That keeps the
invariants testable — e.g. it can never emit ``remove_instance`` or
``to_finetune`` when doing so would leave fewer than ``min_decode``
serving instances.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

ACTIONS = ("none", "add_instance", "remove_instance",
           "to_decode", "to_colocated", "to_finetune",
           "add_prefill", "remove_prefill",
           "grow_chunk_budget", "shrink_chunk_budget")


@dataclasses.dataclass
class AutoscalerConfig:
    interval_s: float = 5.0
    min_decode: int = 1              # serving instances, hard floor
    max_decode: int = 16
    scale_up_load: float = 0.85      # mean serving load above -> grow
    scale_down_load: float = 0.25    # mean serving load below -> shrink
    viol_frac_shed: float = 0.02     # QoS violations above -> shed finetune
    viol_frac_resume: float = 0.005  # below (and backlog) -> resume
    idle_load_ft: float = 0.05       # below (and backlog) -> dedicate to ft
    ft_target_iters_per_s: float = 0.0   # finetune demand; 0 = best-effort
    cooldown_ticks: int = 2          # ticks to wait after any action
    # ---- prefill-pool loop (coordinated with the decode loop: the pool
    # floor tracks the serving fleet so the two tiers move together,
    # ByteDance arXiv 2508.19559-style joint scaling against SLO headroom)
    min_prefill: int = 1             # pool hard floor
    max_prefill: int = 16
    prefill_per_decode: float = 1.0  # coordinated floor: ceil(r * serving)
    prefill_queue_hi: float = 2.0    # queued per worker above -> grow
    ttft_headroom: float = 0.6       # wait_p99 above frac*TTFT-SLO -> grow
    prefill_idle_backlog_s: float = 0.05  # backlog below + empty -> shrink
    prefill_cooldown_ticks: int = 0
    # ---- chunked-mode prefill loop (prefill_mode="chunked"): there is no
    # pool to size, so the same control slot tunes the per-round chunk
    # budget instead — grow when TTFT headroom erodes, give the tokens
    # back to decode/finetune when TTFT is comfortable but TPOT is not
    chunk_step_tokens: int = 64      # budget delta per action


@dataclasses.dataclass(frozen=True)
class InstanceSnapshot:
    inst_id: int
    role: str                        # decode | colocated | finetune
    load: float                      # queue+active over slot budget
    active: int                      # in-flight decode requests
    colocatable: bool                # has a finetune job attached
    can_serve: bool = True           # holds inference weights
    draining: bool = False


@dataclasses.dataclass
class ScaleDecision:
    t: float
    action: str                      # one of ACTIONS
    target: int = -1                 # instance id for role flips / removal
    reason: str = ""


class Autoscaler:
    def __init__(self, cfg: AutoscalerConfig = AutoscalerConfig()):
        self.cfg = cfg
        self.decisions: List[ScaleDecision] = []
        self._cooldown = 0
        self._prefill_cooldown = 0
        self.prefill_ttft_slo_s = 4.0   # set by the cluster (RouterConfig)

    # ------------------------------------------------------------ policy --
    def _decide(self, t: float, snaps: List[InstanceSnapshot],
                viol_frac: float, ft_backlog: float) -> ScaleDecision:
        cfg = self.cfg
        serving = [s for s in snaps if s.role != "finetune"
                   and not s.draining]
        n_serving = len(serving)
        mean_load = (sum(s.load for s in serving) / n_serving) \
            if n_serving else 1.0
        colocated = [s for s in serving if s.role == "colocated"]
        paused = [s for s in serving if s.role == "decode" and s.colocatable]
        dedicated = [s for s in snaps if s.role == "finetune"
                     and s.colocatable and s.can_serve and not s.draining]

        # --- QoS pressure: shed finetune first, then grow the fleet ------
        if viol_frac > cfg.viol_frac_shed:
            if colocated:
                victim = max(colocated, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_decode", victim.inst_id,
                                     f"viol={viol_frac:.3f}")
            if n_serving < cfg.max_decode:
                return ScaleDecision(t, "add_instance",
                                     reason=f"viol={viol_frac:.3f}")
            return ScaleDecision(t, "none", reason="at max_decode")
        if mean_load > cfg.scale_up_load:
            if n_serving < cfg.max_decode:
                return ScaleDecision(t, "add_instance",
                                     reason=f"load={mean_load:.2f}")
            if colocated:
                victim = max(colocated, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_decode", victim.inst_id,
                                     f"load={mean_load:.2f} at max_decode")
            return ScaleDecision(t, "none", reason="at max_decode")

        # --- headroom: give capacity back to finetune --------------------
        if viol_frac < cfg.viol_frac_resume and ft_backlog > 0:
            if paused:
                pick = min(paused, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_colocated", pick.inst_id,
                                     f"backlog={ft_backlog:.1f}")
            idle = [s for s in colocated
                    if s.load <= cfg.idle_load_ft and s.active == 0]
            if idle and n_serving > cfg.min_decode:
                pick = min(idle, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_finetune", pick.inst_id,
                                     f"backlog={ft_backlog:.1f} idle fleet")

        # --- sustained low load: shrink ----------------------------------
        if mean_load < cfg.scale_down_load and n_serving > cfg.min_decode:
            pick = min(serving, key=lambda s: (s.load, s.inst_id))
            return ScaleDecision(t, "remove_instance", pick.inst_id,
                                 f"load={mean_load:.2f}")
        # finetune-dedicated instances rejoin serving when load recovers
        if dedicated and mean_load > 2 * cfg.scale_down_load:
            pick = min(dedicated, key=lambda s: s.inst_id)
            return ScaleDecision(t, "to_colocated", pick.inst_id,
                                 "load recovered")
        return ScaleDecision(t, "none")

    def evaluate(self, t: float, snaps: List[InstanceSnapshot],
                 viol_frac: float, ft_backlog: float = 0.0) -> ScaleDecision:
        """One control tick. Applies cooldown, records the decision."""
        if self._cooldown > 0:
            self._cooldown -= 1
            d = ScaleDecision(t, "none", reason="cooldown")
        else:
            d = self._decide(t, snaps, viol_frac, ft_backlog)
            if d.action != "none":
                self._cooldown = self.cfg.cooldown_ticks
        assert d.action in ACTIONS
        self.decisions.append(d)
        return d

    # -------------------------------------------------- prefill-pool loop --
    def prefill_floor(self, n_serving: int) -> int:
        """Coordinated pool floor: the prefill tier tracks the decode tier
        (``prefill_per_decode`` workers per serving instance) so a decode
        scale-up pulls prefill capacity with it instead of waiting for the
        queue to back up first."""
        cfg = self.cfg
        floor = max(cfg.min_prefill,
                    math.ceil(cfg.prefill_per_decode * n_serving))
        return min(floor, cfg.max_prefill)

    def _decide_prefill(self, t: float, snap, n_serving: int
                        ) -> ScaleDecision:
        """snap: PrefillPoolSnapshot (core/prefill_pool.py) — kept untyped
        here so the controller stays importable without the pool module."""
        cfg = self.cfg
        n = snap.n_workers
        floor = self.prefill_floor(n_serving)
        if n < floor:
            return ScaleDecision(t, "add_prefill",
                                 reason=f"floor={floor} serving={n_serving}")
        # TTFT headroom / queue pressure -> grow
        slo = self.prefill_ttft_slo_s
        if n < cfg.max_prefill:
            if snap.queue_depth > cfg.prefill_queue_hi * max(n, 1):
                return ScaleDecision(t, "add_prefill",
                                     reason=f"queue={snap.queue_depth}")
            if slo > 0 and snap.wait_p99 > cfg.ttft_headroom * slo:
                return ScaleDecision(
                    t, "add_prefill",
                    reason=f"wait_p99={snap.wait_p99:.2f}")
        # deep idle above the coordinated floor -> shrink
        if n > floor and snap.queue_depth == 0 \
                and snap.backlog_s <= cfg.prefill_idle_backlog_s \
                and (slo <= 0 or snap.wait_p99 <
                     0.5 * cfg.ttft_headroom * slo):
            return ScaleDecision(t, "remove_prefill",
                                 reason=f"idle backlog={snap.backlog_s:.2f}")
        return ScaleDecision(t, "none")

    def _decide_chunked(self, t: float, wait_p99: float, viol_frac: float,
                        budget: int, lo: int, hi: int, n_serving: int
                        ) -> ScaleDecision:
        cfg = self.cfg
        slo = self.prefill_ttft_slo_s
        step = cfg.chunk_step_tokens
        # TTFT headroom eroding -> spend more of each round on prefill;
        # once the budget is maxed (or the QoS price caps below it), the
        # only remaining lever is decode capacity itself — in chunked mode
        # prefill capacity IS the decode fleet, so this loop may grow it
        if slo > 0 and wait_p99 > cfg.ttft_headroom * slo:
            if budget < hi:
                # multiplicative increase / additive decrease: a backlog
                # compounds while the budget crawls, so growth must outrun
                # it — escalation to fleet growth then starts within a few
                # ticks instead of after max_budget/step of them
                return ScaleDecision(
                    t, "grow_chunk_budget", target=min(budget * 2, hi),
                    reason=f"chunk_wait_p99={wait_p99:.2f}")
            if n_serving < cfg.max_decode:
                return ScaleDecision(
                    t, "add_instance",
                    reason=f"chunk_wait_p99={wait_p99:.2f} budget maxed")
            return ScaleDecision(t, "none", reason="at max_decode")
        # TTFT comfortable but TPOT under pressure -> hand tokens back
        if budget > lo and viol_frac > cfg.viol_frac_shed and \
                (slo <= 0 or wait_p99 < 0.5 * cfg.ttft_headroom * slo):
            return ScaleDecision(
                t, "shrink_chunk_budget", target=max(budget - step, lo),
                reason=f"viol={viol_frac:.3f}")
        return ScaleDecision(t, "none")

    def evaluate_chunked(self, t: float, wait_p99: float, viol_frac: float,
                         budget: int, lo: int, hi: int, n_serving: int = 0
                         ) -> ScaleDecision:
        """Chunked-mode variant of the prefill control loop: no pool to
        size, so it tunes the fleet-wide per-round chunk budget against
        TTFT headroom (``target`` on the decision carries the new budget),
        escalating to ``add_instance`` once the budget is maxed. Shares
        the prefill loop's cooldown — it occupies the same control slot,
        just mode-aware."""
        if self._prefill_cooldown > 0:
            self._prefill_cooldown -= 1
            d = ScaleDecision(t, "none", reason="prefill cooldown")
        else:
            d = self._decide_chunked(t, wait_p99, viol_frac, budget, lo, hi,
                                     n_serving)
            if d.action != "none":
                self._prefill_cooldown = self.cfg.prefill_cooldown_ticks
        assert d.action in ACTIONS
        self.decisions.append(d)
        return d

    def evaluate_prefill(self, t: float, snap, n_serving: int
                         ) -> ScaleDecision:
        """One prefill-pool control tick (second loop). Own cooldown so a
        decode action never starves the pool of attention; decisions land
        in the same log as the decode loop's."""
        if self._prefill_cooldown > 0:
            self._prefill_cooldown -= 1
            d = ScaleDecision(t, "none", reason="prefill cooldown")
        else:
            d = self._decide_prefill(t, snap, n_serving)
            if d.action != "none":
                self._prefill_cooldown = self.cfg.prefill_cooldown_ticks
        assert d.action in ACTIONS
        self.decisions.append(d)
        return d
