"""Periodic fleet controller: decode-pool sizing + instance role flips.

Runs every ``interval_s`` of simulated time over a snapshot of per-instance
signals and emits at most one action per tick ("Taming the Chaos"-style
coordinated scaling: small reversible steps with a cooldown, never a bulk
reconfiguration). Actions:

  * ``add_instance``      — fleet saturated (high load or QoS violations
                            with no colocation left to shed)
  * ``remove_instance``   — sustained low load; the chosen instance drains
  * ``to_decode(id)``     — QoS under pressure: pause that instance's
                            finetune job (cheapest headroom first, §2.3 —
                            inference always preempts finetune)
  * ``to_colocated(id)``  — QoS headroom back + finetune backlog: resume
  * ``to_finetune(id)``   — deep idle + large backlog: dedicate an idle
                            instance to finetune until load returns
  * ``none``

The controller is pure policy: it never touches instances itself, the
cluster event loop (core/cluster.py) applies decisions. That keeps the
invariants testable — e.g. it can never emit ``remove_instance`` or
``to_finetune`` when doing so would leave fewer than ``min_decode``
serving instances.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

ACTIONS = ("none", "add_instance", "remove_instance",
           "to_decode", "to_colocated", "to_finetune")


@dataclasses.dataclass
class AutoscalerConfig:
    interval_s: float = 5.0
    min_decode: int = 1              # serving instances, hard floor
    max_decode: int = 16
    scale_up_load: float = 0.85      # mean serving load above -> grow
    scale_down_load: float = 0.25    # mean serving load below -> shrink
    viol_frac_shed: float = 0.02     # QoS violations above -> shed finetune
    viol_frac_resume: float = 0.005  # below (and backlog) -> resume
    idle_load_ft: float = 0.05       # below (and backlog) -> dedicate to ft
    ft_target_iters_per_s: float = 0.0   # finetune demand; 0 = best-effort
    cooldown_ticks: int = 2          # ticks to wait after any action


@dataclasses.dataclass(frozen=True)
class InstanceSnapshot:
    inst_id: int
    role: str                        # decode | colocated | finetune
    load: float                      # queue+active over slot budget
    active: int                      # in-flight decode requests
    colocatable: bool                # has a finetune job attached
    can_serve: bool = True           # holds inference weights
    draining: bool = False


@dataclasses.dataclass
class ScaleDecision:
    t: float
    action: str                      # one of ACTIONS
    target: int = -1                 # instance id for role flips / removal
    reason: str = ""


class Autoscaler:
    def __init__(self, cfg: AutoscalerConfig = AutoscalerConfig()):
        self.cfg = cfg
        self.decisions: List[ScaleDecision] = []
        self._cooldown = 0

    # ------------------------------------------------------------ policy --
    def _decide(self, t: float, snaps: List[InstanceSnapshot],
                viol_frac: float, ft_backlog: float) -> ScaleDecision:
        cfg = self.cfg
        serving = [s for s in snaps if s.role != "finetune"
                   and not s.draining]
        n_serving = len(serving)
        mean_load = (sum(s.load for s in serving) / n_serving) \
            if n_serving else 1.0
        colocated = [s for s in serving if s.role == "colocated"]
        paused = [s for s in serving if s.role == "decode" and s.colocatable]
        dedicated = [s for s in snaps if s.role == "finetune"
                     and s.colocatable and s.can_serve and not s.draining]

        # --- QoS pressure: shed finetune first, then grow the fleet ------
        if viol_frac > cfg.viol_frac_shed:
            if colocated:
                victim = max(colocated, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_decode", victim.inst_id,
                                     f"viol={viol_frac:.3f}")
            if n_serving < cfg.max_decode:
                return ScaleDecision(t, "add_instance",
                                     reason=f"viol={viol_frac:.3f}")
            return ScaleDecision(t, "none", reason="at max_decode")
        if mean_load > cfg.scale_up_load:
            if n_serving < cfg.max_decode:
                return ScaleDecision(t, "add_instance",
                                     reason=f"load={mean_load:.2f}")
            if colocated:
                victim = max(colocated, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_decode", victim.inst_id,
                                     f"load={mean_load:.2f} at max_decode")
            return ScaleDecision(t, "none", reason="at max_decode")

        # --- headroom: give capacity back to finetune --------------------
        if viol_frac < cfg.viol_frac_resume and ft_backlog > 0:
            if paused:
                pick = min(paused, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_colocated", pick.inst_id,
                                     f"backlog={ft_backlog:.1f}")
            idle = [s for s in colocated
                    if s.load <= cfg.idle_load_ft and s.active == 0]
            if idle and n_serving > cfg.min_decode:
                pick = min(idle, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_finetune", pick.inst_id,
                                     f"backlog={ft_backlog:.1f} idle fleet")

        # --- sustained low load: shrink ----------------------------------
        if mean_load < cfg.scale_down_load and n_serving > cfg.min_decode:
            pick = min(serving, key=lambda s: (s.load, s.inst_id))
            return ScaleDecision(t, "remove_instance", pick.inst_id,
                                 f"load={mean_load:.2f}")
        # finetune-dedicated instances rejoin serving when load recovers
        if dedicated and mean_load > 2 * cfg.scale_down_load:
            pick = min(dedicated, key=lambda s: s.inst_id)
            return ScaleDecision(t, "to_colocated", pick.inst_id,
                                 "load recovered")
        return ScaleDecision(t, "none")

    def evaluate(self, t: float, snaps: List[InstanceSnapshot],
                 viol_frac: float, ft_backlog: float = 0.0) -> ScaleDecision:
        """One control tick. Applies cooldown, records the decision."""
        if self._cooldown > 0:
            self._cooldown -= 1
            d = ScaleDecision(t, "none", reason="cooldown")
        else:
            d = self._decide(t, snaps, viol_frac, ft_backlog)
            if d.action != "none":
                self._cooldown = self.cfg.cooldown_ticks
        assert d.action in ACTIONS
        self.decisions.append(d)
        return d
