"""Asynchronous cache-summary gossip plane for fleet-scale routing.

``cache_aware`` routing (PR 5) synchronously peeks every candidate's
prefix cache on every dispatch — O(fleet) cache probes per request,
which does not survive fleets well beyond 16 instances. This module
models the alternative every large serving fleet converges on: each
instance periodically publishes a *compact digest* of its prefix tree
(top-k prefix fingerprints + cached token counts, bounded bytes), and
the router scores placements from the digests alone — **zero
synchronous peeks on the dispatch path**.

The price of asynchrony is staleness: a digest describes the cache as
it was up to one gossip period ago (plus propagation delay, which we
fold into the period). ``cache_aware_gossip`` therefore discounts the
estimated hit linearly with digest age and a digest at or past the
``staleness_bound_s`` is *never* used (``get`` returns ``None``, the
instance scores as a cold cache). The staleness math and the decision
table vs synchronous ``cache_aware`` are in docs/cluster.md.

Everything here is deterministic (dict state keyed by instance id, the
simulator's clock, no RNG) — gossip runs are bit-reproducible per seed.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

# Modeled wire format: a fixed header (instance id, publish time, total /
# capacity token counters) plus one (fingerprint, token count) entry per
# digest slot. 64-bit fingerprint + 32-bit token count per entry.
DIGEST_HEADER_BYTES = 24
DIGEST_ENTRY_BYTES = 12


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    """Spec block ``cluster.gossip`` (ExperimentSpec schema v2)."""

    period_s: float = 2.0            # publish interval per instance
    staleness_bound_s: float = 10.0  # digests at/past this age are dead
    top_k: int = 8                   # prefix fingerprints per digest
    max_bytes: int = 256             # hard cap on digest wire size

    def effective_top_k(self) -> int:
        """``top_k`` after the byte budget: entries that do not fit in
        ``max_bytes`` are dropped heaviest-last (the digest is sorted by
        token mass, so the cheap-to-lose tail goes first)."""
        budget = (self.max_bytes - DIGEST_HEADER_BYTES) // DIGEST_ENTRY_BYTES
        return max(min(self.top_k, budget), 0)


@dataclasses.dataclass(frozen=True)
class CacheDigest:
    """One instance's published cache summary, immutable once published."""

    inst_id: int
    t: float                                   # publish time
    total_tokens: int                          # tree-wide cached tokens
    capacity_tokens: int
    entries: Tuple[Tuple[int, int], ...]       # (fingerprint, tokens), heavy first
    size_bytes: int

    def age(self, now: float) -> float:
        return max(now - self.t, 0.0)


class GossipPlane:
    """The fleet-wide digest store: publish side driven by the
    ``ClusterSim`` step loop (one pump per epoch, per-instance period),
    read side driven by the ``cache_aware_gossip`` policy at dispatch.

    In a real deployment this is a gossip/broadcast bus; the simulator
    models its *information* properties (bounded size, bounded
    staleness, periodic refresh) rather than its transport.
    """

    def __init__(self, cfg: GossipConfig):
        self.cfg = cfg
        self._digests: Dict[int, CacheDigest] = {}
        self.published = 0
        self.bytes_published = 0
        self.reads = 0
        self.stale_discards = 0
        self.max_used_age = 0.0

    def publish(self, inst_id: int, now: float, tree) -> CacheDigest:
        """Snapshot ``tree`` into a digest for ``inst_id`` at ``now``.
        ``tree`` is a ``RadixPrefixTree`` (duck-typed: ``digest(k)``,
        ``used_tokens``, ``capacity_tokens``)."""
        k = self.cfg.effective_top_k()
        entries = tuple(tree.digest(k))
        d = CacheDigest(
            inst_id=inst_id,
            t=now,
            total_tokens=tree.used_tokens,
            capacity_tokens=tree.capacity_tokens,
            entries=entries,
            size_bytes=DIGEST_HEADER_BYTES + DIGEST_ENTRY_BYTES * len(entries),
        )
        self._digests[inst_id] = d
        self.published += 1
        self.bytes_published += d.size_bytes
        return d

    def get(self, inst_id: int, now: float) -> Optional[CacheDigest]:
        """The freshest digest for ``inst_id``, or ``None`` when there is
        none or it has aged past the staleness bound — the caller must
        treat ``None`` as an unknown (cold) cache, never fall back to a
        synchronous peek."""
        d = self._digests.get(inst_id)
        if d is None:
            return None
        age = d.age(now)
        if age >= self.cfg.staleness_bound_s:
            self.stale_discards += 1
            return None
        self.reads += 1
        if age > self.max_used_age:
            self.max_used_age = age
        return d

    def discount(self, age: float) -> float:
        """Hit-probability multiplier for a digest of ``age``: linear
        decay from 1 (fresh) to 0 at the staleness bound. The cache may
        have evicted what the digest advertises; the closer to the bound,
        the less the advertisement is worth."""
        bound = self.cfg.staleness_bound_s
        if bound <= 0:
            return 0.0
        return max(1.0 - age / bound, 0.0)

    def drop(self, inst_id: int) -> None:
        """Forget an instance's digest (killed / preempted — its cache is
        gone, advertising it would misroute until the bound expired)."""
        self._digests.pop(inst_id, None)

    def __len__(self) -> int:
        return len(self._digests)
