"""Two-stage latency predictor (paper §5).

Stage 1 — solo decode latency, one LR model per quantum level (paper: per SM
ratio, Eq. 2):      L(bs, s) = bs*b0 + c0 + bs*k0*s
Stage 2 — co-located decode latency (Eq. 3):
                    L_colo = (q_inf*b1 + q_ft*k1) * L_solo@q_inf

Fitting follows §8.8 exactly: three batch sizes (4, 16, 64), sequence lengths
up to 512, 10 quantum levels, numpy lstsq. The measurement source is the
roofline cost simulator (the container's stand-in for real profiling);
the fit/predict code path is production-identical.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.costmodel import CostModel

PROFILE_BS = (4, 16, 64)
PROFILE_SEQLENS = tuple(range(64, 513, 64))


@dataclasses.dataclass
class FitReport:
    solo_fit_s: float = 0.0
    colo_fit_s: float = 0.0
    solo_samples: int = 0
    colo_samples: int = 0
    solo_mean_err: float = 0.0
    solo_max_err: float = 0.0
    colo_mean_err: float = 0.0          # roofline-max (production) form
    colo_max_err: float = 0.0
    colo_paper_mean_err: float = 0.0    # Eq. 3 verbatim, under fusion
    colo_paper_max_err: float = 0.0
    mixed_fit_s: float = 0.0            # chunked-prefill stage
    mixed_samples: int = 0
    mixed_mean_err: float = 0.0
    mixed_max_err: float = 0.0
    mixed_fused_samples: int = 0        # fused-quantum chunk rounds
    mixed_fused_mean_err: float = 0.0
    mixed_fused_max_err: float = 0.0


class TwoStageLatencyPredictor:
    """q_ft = k/k_max is the finetune quantum (TPU analogue of the SM ratio);
    q_inf = 1 - q_ft."""

    def __init__(self, k_max: int = 10):
        self.k_max = k_max
        self.quanta = [i / k_max for i in range(k_max + 1)]
        self.solo_coef: Dict[float, np.ndarray] = {}   # q_inf -> (b0, c0, k0)
        self.colo_coef: Optional[np.ndarray] = None    # Eq. 3 (b1, k1)
        self.colo_lr_coef: Optional[np.ndarray] = None  # roofline-LR
        self.mixed_coef: Optional[np.ndarray] = None    # chunked-prefill
        self.mixed_fused_coef: Optional[np.ndarray] = None  # q_ft>0 rounds
        self.report = FitReport()

    # ------------------------------------------------------------- stage 1
    @staticmethod
    def _solo_features(bs, s):
        bs = np.asarray(bs, np.float64)
        s = np.asarray(s, np.float64)
        return np.stack([bs, np.ones_like(bs), bs * s], axis=-1)

    def fit_solo(self, samples: Dict[float, List[Tuple[int, int, float]]]
                 ) -> None:
        """samples: q_inf -> [(bs, seqlen, latency_s)]."""
        t0 = time.perf_counter()
        errs = []
        for q, rows in samples.items():
            bs = np.array([r[0] for r in rows], np.float64)
            s = np.array([r[1] for r in rows], np.float64)
            y = np.array([r[2] for r in rows], np.float64)
            X = self._solo_features(bs, s)
            coef, *_ = np.linalg.lstsq(X, y, rcond=None)
            self.solo_coef[round(q, 6)] = coef
            pred = X @ coef
            errs.extend(np.abs(pred - y) / np.maximum(y, 1e-9))
            self.report.solo_samples += len(rows)
        self.report.solo_fit_s = time.perf_counter() - t0
        self.report.solo_mean_err = float(np.mean(errs))
        self.report.solo_max_err = float(np.max(errs))

    def predict_solo(self, q_inf: float, bs: float, seqlen: float) -> float:
        key = min(self.solo_coef, key=lambda q: abs(q - q_inf))
        b0, c0, k0 = self.solo_coef[key]
        return float(bs * b0 + c0 + bs * k0 * seqlen)

    # ------------------------------------------------------------- stage 2
    #
    # Two co-location forms:
    #  * "paper"        — Eq. 3 verbatim: (q_inf*b1 + q_ft*k1) * L_solo@q_inf.
    #    Exact under *spatial* partitioning (the paper's GPU setting).
    #  * "roofline-max" — TPU adaptation: under temporal fusion the paper's
    #    own contention law (Eq. 4-5) yields a roofline, i.e. the max of two
    #    linear terms (memory-bound and compute-bound) in the same two
    #    regressors (solo latency, finetune quantum). Fit by 2-regime EM
    #    over plain lstsq. This is the production predictor; Fig. 12
    #    benchmarks report both.
    def _colo_features(self, q_ft, bs, s):
        base = self.predict_solo(1.0, bs, s)
        return np.array([base, q_ft, q_ft * base, 1.0], np.float64)

    def fit_colo(self, samples: List[Tuple[float, float, int, int, float]]
                 ) -> None:
        """samples: [(q_inf, q_ft, bs, seqlen, latency_s)]. One model across
        all (bs, seqlen) — paper §8.8."""
        t0 = time.perf_counter()
        # --- paper form (Eq. 3) ------------------------------------------
        Xp, y = [], []
        for q_inf, q_ft, bs, s, lat in samples:
            base = self.predict_solo(q_inf, bs, s)
            Xp.append([q_inf * base, q_ft * base])
            y.append(lat)
        Xp = np.asarray(Xp, np.float64)
        y = np.asarray(y, np.float64)
        self.colo_coef, *_ = np.linalg.lstsq(Xp, y, rcond=None)
        rel_p = np.abs(Xp @ self.colo_coef - y) / np.maximum(y, 1e-9)
        self.report.colo_paper_mean_err = float(np.mean(rel_p))
        self.report.colo_paper_max_err = float(np.max(rel_p))

        # --- roofline-LR form ---------------------------------------------
        # single lstsq on [L_solo, q_ft, q_ft*L_solo, 1]: the q_ft term is
        # the finetune units' compute slope, the interaction term captures
        # the bandwidth-contention coupling (Eq. 5). Deterministic and
        # seed-stable (a max-of-two-affine EM fit was tried and is worse —
        # see EXPERIMENTS.md §Perf, refuted-hypothesis log).
        X = np.stack([self._colo_features(q_ft, bs, s)
                      for _, q_ft, bs, s, _ in samples])
        self.colo_lr_coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        pred = X @ self.colo_lr_coef
        rel = np.abs(pred - y) / np.maximum(y, 1e-9)
        self.report.colo_fit_s = time.perf_counter() - t0
        self.report.colo_samples = len(y)
        self.report.colo_mean_err = float(np.mean(rel))
        self.report.colo_max_err = float(np.max(rel))

    def predict_colo(self, q_ft: float, bs: float, seqlen: float,
                     form: str = "roofline-max") -> float:
        """Predicted decode latency when q_ft of the round is granted to
        finetune units. q_ft=0 falls back to the stage-1 solo model."""
        if q_ft <= 0 or self.colo_lr_coef is None:
            return self.predict_solo(1.0, bs, seqlen)
        if form == "paper":
            q_inf = 1.0 - q_ft
            base = self.predict_solo(q_inf, bs, seqlen)
            b1, k1 = self.colo_coef
            return float((q_inf * b1 + q_ft * k1) * base)
        return float(self._colo_features(q_ft, bs, seqlen)
                     @ self.colo_lr_coef)

    # ------------------------------------------- stage 3 (chunked prefill)
    #
    # Mixed-round model for prefill_mode="chunked" (core/simulator.py): a
    # decode round that also carries `chunk_tokens` of prefill work. The
    # chunk's FLOPs are additive on the fused round's critical path (the
    # same linearity Eq. 5 gives the finetune quantum), so the model is
    # linear in the co-location baseline and the chunk size:
    #     L_mixed = a * L_colo(q_ft, bs, s) + b * chunk_tokens + c
    # Its inverse (`max_chunk_tokens`) is what the chunked scheduler uses
    # to price a chunk's TPOT impact BEFORE admitting it into a round —
    # the QoS guarantee stays prediction-driven, exactly like the finetune
    # quantum path.
    def _mixed_features(self, q_ft, bs, s, chunk_tokens):
        base = self.predict_colo(q_ft, bs, s)
        return np.array([base, float(chunk_tokens), 1.0], np.float64)

    def fit_mixed(self, samples: List[Tuple[float, int, int, int, float]]
                  ) -> None:
        """samples: [(q_ft, bs, seqlen, chunk_tokens, latency_s)]."""
        t0 = time.perf_counter()
        X = np.stack([self._mixed_features(q, bs, s, ct)
                      for q, bs, s, ct, _ in samples])
        y = np.array([lat for *_, lat in samples], np.float64)
        self.mixed_coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        rel = np.abs(X @ self.mixed_coef - y) / np.maximum(y, 1e-9)
        self.report.mixed_fit_s = time.perf_counter() - t0
        self.report.mixed_samples = len(y)
        self.report.mixed_mean_err = float(np.mean(rel))
        self.report.mixed_max_err = float(np.max(rel))

    def predict_mixed(self, q_ft: float, bs: float, seqlen: float,
                      chunk_tokens: int) -> float:
        """Predicted round latency with a prefill chunk mixed in."""
        if chunk_tokens <= 0 or self.mixed_coef is None:
            return self.predict_colo(q_ft, bs, seqlen)
        return float(self._mixed_features(q_ft, bs, seqlen, chunk_tokens)
                     @ self.mixed_coef)

    # -------------------------------------- fused-quantum chunk rounds
    #
    # ``ChunkedPrefillConfig.fuse_quantum`` prices rounds that carry BOTH
    # a prefill chunk and a reduced finetune quantum. The base mixed
    # stage is profiled exclusively at q_ft=0 (its inverse prices the
    # chunk cap on quantum-0 rounds and must stay bit-stable), so
    # extrapolating it to q_ft>0 carries 25-45% error at large quanta.
    # This stage refits the same linear form on samples that *include*
    # q_ft>0 rounds, so the fused admission check interpolates instead.
    def fit_mixed_fused(self, samples: List[Tuple[float, int, int, int,
                                                  float]]) -> None:
        """samples: [(q_ft, bs, seqlen, chunk_tokens, latency_s)] with
        q_ft spanning 0..~0.8."""
        X = np.stack([self._mixed_features(q, bs, s, ct)
                      for q, bs, s, ct, _ in samples])
        y = np.array([lat for *_, lat in samples], np.float64)
        self.mixed_fused_coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        rel = np.abs(X @ self.mixed_fused_coef - y) / np.maximum(y, 1e-9)
        self.report.mixed_fused_samples = len(y)
        self.report.mixed_fused_mean_err = float(np.mean(rel))
        self.report.mixed_fused_max_err = float(np.max(rel))

    def predict_mixed_fused(self, q_ft: float, bs: float, seqlen: float,
                            chunk_tokens: int) -> float:
        """Predicted latency of a round carrying a chunk AND a finetune
        quantum — the fused-admission price check. Falls back to the
        q_ft=0 mixed stage when the fused stage is not fitted."""
        if self.mixed_fused_coef is None:
            return self.predict_mixed(q_ft, bs, seqlen, chunk_tokens)
        if chunk_tokens <= 0:
            return self.predict_colo(q_ft, bs, seqlen)
        return float(self._mixed_features(q_ft, bs, seqlen, chunk_tokens)
                     @ self.mixed_fused_coef)

    def max_chunk_tokens(self, q_ft: float, bs: float, seqlen: float,
                         limit_s: float, cap: int) -> int:
        """Largest prefill chunk (<= cap) whose predicted mixed-round
        latency stays under ``limit_s`` — the admission price check."""
        if self.mixed_coef is None:
            return cap
        a, b, c = self.mixed_coef
        base = self.predict_colo(q_ft, bs, seqlen)
        if b <= 0:                       # degenerate fit: no per-token cost
            return cap
        room = limit_s - (a * base + c)
        return int(max(min(room / b, float(cap)), 0.0))

    def predict_latency_us(self) -> float:
        """Runtime prediction cost (paper §8.8 reports ~5us)."""
        t0 = time.perf_counter()
        n = 1000
        for i in range(n):
            self.predict_colo(0.3, 16, 256)
        return (time.perf_counter() - t0) / n * 1e6

    # --------------------------------------------------- profiling driver
    def fit_from_costmodel(self, cm: CostModel, micro_batch: int = 2,
                           ft_seq: int = 1024) -> FitReport:
        """Paper §8.8 offline profiling schedule, against the cost model.

        Solo: 10 quantum levels x 3 batch sizes x seqlens<=512, one decode
        round each. Colo: 45 (q_inf, q_ft) pairs at 3 batch sizes."""
        solo: Dict[float, List[Tuple[int, int, float]]] = {}
        for q in self.quanta[1:]:                 # q_inf in 0.1..1.0
            rows = []
            for bs in PROFILE_BS:
                for s in PROFILE_SEQLENS:
                    rows.append((bs, s, cm.decode_solo(bs, s, quantum=q)))
            solo[q] = rows
        self.fit_solo(solo)

        colo = []
        for ki in range(1, self.k_max):           # q_ft = ki/k_max
            q_ft = ki / self.k_max
            for bs in PROFILE_BS:
                for s in (128, 256, 512):
                    lat = cm.colocated_round(bs, s, ki, micro_batch, ft_seq)
                    colo.append((1.0 - q_ft, q_ft, bs, s, lat))
        self.fit_colo(colo)

        # chunked-prefill stage: decode rounds carrying a prefill chunk.
        # Profiled at q_ft=0 — the chunked scheduler preempts finetune on
        # chunk rounds (inference work beats finetune, §2.3), so that is
        # the operating point the inverse (max_chunk_tokens) prices.
        mixed = []
        for bs in PROFILE_BS:
            for s in (128, 256, 512):
                for ct in (64, 128, 256, 512):
                    lat = cm.mixed_round_latency(bs, s, ct, chunk_ctx=s)
                    mixed.append((0.0, bs, s, ct, lat))
        self.fit_mixed(mixed)

        # fused-quantum stage (fuse_quantum rounds: chunk + reduced
        # quantum). Sampled AFTER everything above so the q_ft=0 stages'
        # samples — and therefore their coefficients and every seeded
        # noise draw they consume — are bit-identical with or without it.
        fused = list(mixed)
        # low/mid/high quanta scaled to k_max (== (2, 5, 8) at the
        # default k_max=10); every sample stays physically reachable
        ks = sorted({max(self.k_max // 5, 1), max(self.k_max // 2, 1),
                     max(4 * self.k_max // 5, 1)})
        for ki in ks:
            q_ft = ki / self.k_max
            for bs in PROFILE_BS:
                for s in (128, 256, 512):
                    for ct in (64, 256):
                        lat = cm.mixed_round_latency(
                            bs, s, ct, chunk_ctx=s, k_units=ki,
                            micro_batch=micro_batch, seq_len=ft_seq)
                        fused.append((q_ft, bs, s, ct, lat))
        self.fit_mixed_fused(fused)
        return self.report
