"""Unified memory allocator (paper §4) — TPU adaptation.

One arbiter owns the instance's unified HBM pool (everything left after the
inference model's weights). Three typed sub-pools share it:

  * KV pool        — chunk-granular (chunk = n_layers x 2 blocks, block 2MB),
                     exactly the paper's two-level layout;
  * finetune window — whole chunks lent to the finetune task to hold frozen
                     layer weights (window-based swapping, §4.3);
  * small-tensor pool — fixed-size buddy-managed region (2KB granularity)
                     for sub-2MB activations (§4.5);
  * prefix cache    — whole chunks lent to the session prefix cache
                     (core/prefix_cache.py) so sticky-session KV reuse is
                     charged against the same reusable pool as the window;
  * adapter pool    — whole chunks holding hot-loaded LoRA adapter weights
                     (core/adapters.py): multi-tenant serving competes for
                     the same HBM as KV admission and the finetune window.

Mechanism difference vs the paper (recorded in DESIGN.md §2): CUDA VMM
remapping is replaced by budget re-partitioning at decode-round boundaries
(JAX buffer donation); the *policies* — window sizing from free chunks,
reserved headroom Mem_reserved = (T_swap/QoS)·max_bs·Mem_kv, immediate
reclaim within one swap latency — are the paper's.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.buddy import BuddyAllocator

BLOCK_BYTES = 2 * 1024 * 1024


@dataclasses.dataclass
class AllocatorConfig:
    total_bytes: int               # unified pool size (per instance)
    n_layers: int                  # inference model depth (chunk geometry)
    kv_bytes_per_token: int        # across all layers
    max_bs: int                    # max decode batch (headroom formula)
    qos_s: float                   # decode QoS target (50ms in §4.4 formula)
    swap_time_s: float             # T: time to swap one finetune layer
    small_pool_bytes: int = 256 * 1024 * 1024
    block_bytes: int = BLOCK_BYTES


class UnifiedAllocator:
    def __init__(self, cfg: AllocatorConfig):
        self.cfg = cfg
        self.chunk_bytes = cfg.n_layers * 2 * cfg.block_bytes
        pool = cfg.total_bytes - cfg.small_pool_bytes
        assert pool > 0, "pool smaller than small-tensor region"
        self.total_chunks = pool // self.chunk_bytes
        assert self.total_chunks > 0, "pool smaller than one chunk"
        self.kv_chunks = 0
        self.window_chunks = 0
        self.prefix_chunks = 0         # session prefix cache (prefix_cache.py)
        self.adapter_chunks = 0        # resident LoRA adapters (adapters.py)
        self.kv_tokens = 0
        self.reclaims = 0              # window chunks reclaimed by KV pressure
        # paired-accounting audit for adapter churn: every chunk reserved
        # must eventually be released; adapter_leak exposes the difference
        self.adapter_reserved_total = 0
        self.adapter_released_total = 0
        self.small = BuddyAllocator(cfg.small_pool_bytes)
        # metrics timeline for Fig. 13
        self.timeline: List[Dict] = []

    # ------------------------------------------------------- geometry ----
    @property
    def tokens_per_chunk(self) -> int:
        return max(self.chunk_bytes // max(self.cfg.kv_bytes_per_token, 1), 1)

    @property
    def free_chunks(self) -> int:
        return self.total_chunks - self.kv_chunks - self.window_chunks \
            - self.prefix_chunks - self.adapter_chunks

    @property
    def reserved_chunks(self) -> int:
        """Paper §4.4: Mem_reserved = (T_swap / QoS) * max_bs * Mem_kv —
        enough KV headroom that inference never waits for a window shrink."""
        tokens = math.ceil(self.cfg.swap_time_s / self.cfg.qos_s
                           * self.cfg.max_bs)
        reserved_bytes = tokens * self.cfg.kv_bytes_per_token
        return max(math.ceil(reserved_bytes / self.chunk_bytes), 1)

    # ------------------------------------------------------------ KV -----
    def kv_capacity_tokens(self) -> int:
        return self.kv_chunks * self.tokens_per_chunk

    def kv_alloc_tokens(self, n_tokens: int) -> bool:
        """Grow the KV pool to hold n more tokens. Inference is prioritized
        (paper §2.3): when free chunks don't cover the growth, the window is
        reclaimed on the spot — the reserved headroom guarantees the reclaim
        latency is hidden (§4.4); the finetune side observes the shrink on
        its next pump and evicts. Returns False only when genuinely OOM."""
        need_total = self.kv_tokens + n_tokens
        need_chunks = math.ceil(need_total / self.tokens_per_chunk)
        grow = need_chunks - self.kv_chunks
        if grow > 0:
            short = grow - self.free_chunks
            if short > 0:
                if short > self.window_chunks:
                    return False        # truly out of memory
                self.window_chunks -= short
                self.reclaims += short
            self.kv_chunks += grow
        self.kv_tokens = need_total
        return True

    def kv_free_tokens(self, n_tokens: int) -> None:
        self.kv_tokens = max(self.kv_tokens - n_tokens, 0)
        need_chunks = math.ceil(self.kv_tokens / self.tokens_per_chunk) \
            if self.kv_tokens else 0
        self.kv_chunks = max(need_chunks, 0)

    # --------------------------------------------------------- prefix ----
    def prefix_reserve(self, chunks: int) -> int:
        """Carve session-prefix-cache capacity out of the reusable pool.
        Charged like the finetune window — it shrinks both the window
        capacity and (via the caller reducing its KV admission budget) the
        KV pool — so cached prefixes are real memory, not free TTFT. The
        grant never eats the §4.4 reserved headroom. Returns chunks
        granted (may be fewer than asked)."""
        granted = max(min(chunks, self.free_chunks - self.reserved_chunks),
                      0)
        self.prefix_chunks += granted
        return granted

    # -------------------------------------------------------- adapters ----
    def adapter_reserve(self, chunks: int) -> bool:
        """Pin a LoRA adapter's weight chunks. Adapters serve inference, so
        like KV growth they may reclaim finetune-window chunks on the spot —
        but the grant is all-or-nothing (partial adapter weights are useless)
        and never eats the §4.4 reserved headroom. Returns False when the
        adapter genuinely does not fit (caller evicts a colder adapter and
        retries, or serves at the base model)."""
        if chunks <= 0:
            return True
        avail = max(self.free_chunks - self.reserved_chunks, 0) \
            + self.window_chunks
        if chunks > avail:
            return False
        short = chunks - max(self.free_chunks - self.reserved_chunks, 0)
        if short > 0:
            self.window_chunks -= short
            self.reclaims += short
        self.adapter_chunks += chunks
        self.adapter_reserved_total += chunks
        return True

    def adapter_release(self, chunks: int) -> None:
        assert 0 <= chunks <= self.adapter_chunks
        self.adapter_chunks -= chunks
        self.adapter_released_total += chunks

    @property
    def adapter_leak(self) -> int:
        """Reserve/release pairing audit: nonzero means an adapter load or
        eviction lost track of chunks. Asserted zero by check_invariants."""
        return self.adapter_reserved_total - self.adapter_released_total \
            - self.adapter_chunks

    # --------------------------------------------------------- window ----
    def window_capacity_chunks(self) -> int:
        """How many chunks the finetune window may hold right now: free
        chunks minus the reserved headroom (§4.4)."""
        return max(self.free_chunks + self.window_chunks
                   - self.reserved_chunks, 0)

    def resize_window(self, chunks: int) -> int:
        """Clamp to capacity; returns the granted window size (chunks)."""
        granted = min(chunks, self.window_capacity_chunks())
        self.window_chunks = max(granted, 0)
        return self.window_chunks

    def pressure_shrink(self) -> int:
        """Called when KV needs memory: shed window chunks down to what the
        current capacity allows. Returns chunks released."""
        cap = self.window_capacity_chunks()
        released = max(self.window_chunks - cap, 0)
        self.window_chunks -= released
        return released

    # --------------------------------------------------------- metrics ---
    def snapshot(self, t: float) -> Dict:
        s = {
            "t": t,
            "kv_bytes": self.kv_chunks * self.chunk_bytes,
            "window_bytes": self.window_chunks * self.chunk_bytes,
            "prefix_bytes": self.prefix_chunks * self.chunk_bytes,
            "adapter_bytes": self.adapter_chunks * self.chunk_bytes,
            "small_bytes": self.cfg.small_pool_bytes,
            "free_bytes": self.free_chunks * self.chunk_bytes,
            "kv_tokens": self.kv_tokens,
            "window_chunks": self.window_chunks,
        }
        self.timeline.append(s)
        return s

    def check_invariants(self) -> None:
        assert 0 <= self.kv_chunks
        assert 0 <= self.window_chunks
        assert 0 <= self.prefix_chunks
        assert 0 <= self.adapter_chunks
        assert self.adapter_leak == 0
        assert self.kv_chunks + self.window_chunks + self.prefix_chunks \
            + self.adapter_chunks <= self.total_chunks
        assert self.kv_tokens <= self.kv_capacity_tokens() or \
            self.kv_chunks == 0
