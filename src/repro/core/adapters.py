"""Multi-LoRA adapter lifecycle: the finetune→serve loop.

Harli's colocated finetune jobs *produce* LoRA adapters; this module makes
the fleet *serve* them, closing the MaaS loop the ROADMAP calls
"continuous adapter deployment":

  * ``AdapterRegistry``      — fleet-level versioned store. The cluster
    publishes a new version for each tenant's adapter as its finetune job
    accumulates iterations (``AdapterServingConfig.publish_every_iters``).
  * ``AdapterPool``          — per-instance runtime. Decode instances
    hot-load the (adapter_id, version) a request was stamped with on
    demand; the weight bytes are charged to the instance's
    ``UnifiedAllocator`` (``adapter_reserve``/``adapter_release``), so
    resident adapters genuinely compete with KV admission, the finetune
    window and prefix-cache reservations. Load/swap time is priced by
    ``CostModel.adapter_load_time`` into the decode round the load lands
    in.
  * ``TenantConfig``         — a tenant in the arrival mix: its traffic
    weight and optional per-tenant TTFT/TPOT SLOs (threaded onto every
    request so ``request_slo`` scores each tenant against its own target).

Placement (which instance should serve an adapter-carrying request) is a
pluggable policy kind — ``adapter_placement`` in core/api.py, builtins in
core/policies/adapter_placement.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.core.allocator import UnifiedAllocator
from repro.models.config import LoRAConfig, ModelConfig


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant of the multi-tenant arrival mix. ``weight`` is its share
    of arrivals (normalized across tenants); the SLO fields override the
    router-wide targets for this tenant's requests (None = router default).
    A tenant's adapter_id is its index in ``ExperimentSpec.tenants``."""
    name: str = "tenant"
    weight: float = 1.0
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class AdapterServingConfig:
    """Cluster-level switch for multi-LoRA serving (None = off, and the
    whole subsystem is inert — bit-identical to the adapter-less sim)."""
    rank: int = 16                   # LoRA rank of the served adapters
    publish_every_iters: float = 1.0  # finetune iters between versions
    continuous: bool = True          # False = static baseline: v1 only
    max_loaded: int = 0              # per-instance residency cap (0 = HBM-bound)
    policy: str = "affinity_packed"  # adapter_placement registry name


@dataclasses.dataclass(frozen=True)
class InstanceAdapterConfig:
    """Per-instance geometry the cluster derives once from the model and
    AdapterServingConfig: chunk footprint and DMA load time of one adapter."""
    chunks: int                      # allocator chunks per resident adapter
    load_time_s: float               # CostModel.adapter_load_time(bytes)
    max_loaded: int = 0


def adapter_bytes(cfg: ModelConfig, rank: int) -> float:
    """bf16 weight bytes of one LoRA adapter at ``rank`` for this model."""
    lora = cfg.lora if cfg.lora is not None else LoRAConfig()
    scaled = dataclasses.replace(cfg, lora=dataclasses.replace(
        lora, rank=rank))
    return scaled.lora_param_count() * 2.0


class AdapterRegistry:
    """Fleet-level versioned adapter store. Versions are monotone per
    adapter; ``publish`` of a non-increasing version is a no-op so the
    cluster can republish idempotently every epoch."""

    def __init__(self) -> None:
        self._latest: Dict[int, int] = {}
        # (t, adapter_id, version) in publish order — the deployment log
        self.published: List[Tuple[float, int, int]] = []

    def publish(self, adapter_id: int, version: int, t: float) -> bool:
        if version <= self._latest.get(adapter_id, 0):
            return False
        self._latest[adapter_id] = version
        self.published.append((t, adapter_id, version))
        return True

    def latest(self, adapter_id: int) -> int:
        """Newest published version (0 = never published: serve base)."""
        return self._latest.get(adapter_id, 0)

    @property
    def versions_published(self) -> int:
        return len(self.published)


class AdapterPool:
    """Per-instance adapter residency. ``require`` queues a hot-load at
    request admission; ``take_load_time`` performs the queued loads at the
    next decode round (evicting LRU adapters not pinned by in-flight
    requests when HBM is short) and returns the DMA seconds to fold into
    that round's latency. All weight chunks go through the allocator's
    paired adapter_reserve/adapter_release so churn is leak-audited."""

    def __init__(self, alloc: UnifiedAllocator,
                 cfg: InstanceAdapterConfig) -> None:
        self.alloc = alloc
        self.cfg = cfg
        self.resident: Dict[int, int] = {}      # adapter_id -> version
        self._lru: Dict[int, int] = {}          # adapter_id -> last-use tick
        self._tick = 0
        self._queued: List[Tuple[int, int]] = []  # pending (aid, version)
        self.loads = 0
        self.evictions = 0
        self.load_failures = 0                  # served at base model instead
        self.load_time_total_s = 0.0

    def has(self, adapter_id: int, version: int) -> bool:
        return self.resident.get(adapter_id) == version

    def require(self, adapter_id: int, version: int) -> None:
        """Mark (adapter_id, version) needed; refreshes LRU recency either
        way so an already-resident adapter isn't the next eviction victim."""
        if adapter_id < 0:
            return
        self._tick += 1
        self._lru[adapter_id] = self._tick
        if self.resident.get(adapter_id) == version:
            return
        if (adapter_id, version) not in self._queued:
            self._queued.append((adapter_id, version))

    def take_load_time(self, in_use: Set[int]) -> float:
        """Perform all queued loads now; returns total load seconds charged
        to the current round. ``in_use`` is the set of adapter ids pinned
        by in-flight requests — never evicted to make room."""
        if not self._queued:
            return 0.0
        total = 0.0
        queued, self._queued = self._queued, []
        for aid, ver in queued:
            if self.resident.get(aid) == ver:
                continue            # a later require already satisfied it
            if self._load(aid, ver, in_use):
                total += self.cfg.load_time_s
                self.loads += 1
            else:
                self.load_failures += 1
        self.load_time_total_s += total
        return total

    def _load(self, aid: int, ver: int, in_use: Set[int]) -> bool:
        # version swap: the old version's chunks are released first, so an
        # upgrade never needs net-new HBM
        if aid in self.resident:
            self._evict(aid)
        while not self._fits():
            if not self._evict_coldest(in_use, protect=aid):
                return False        # nothing evictable: serve at base
        if not self.alloc.adapter_reserve(self.cfg.chunks):
            # allocator-level shortage (KV/prefix pressure): shed colder
            # adapters until the reserve succeeds or nothing is left
            while self._evict_coldest(in_use, protect=aid):
                if self.alloc.adapter_reserve(self.cfg.chunks):
                    break
            else:
                return False
        self.resident[aid] = ver
        return True

    def _fits(self) -> bool:
        return self.cfg.max_loaded <= 0 \
            or len(self.resident) < self.cfg.max_loaded

    def _evict_coldest(self, in_use: Set[int], protect: int) -> bool:
        victims = [a for a in self.resident
                   if a not in in_use and a != protect]
        if not victims:
            return False
        self._evict(min(victims, key=lambda a: self._lru.get(a, 0)))
        return True

    def _evict(self, aid: int) -> None:
        del self.resident[aid]
        self.alloc.adapter_release(self.cfg.chunks)
        self.evictions += 1

    def evict_all(self) -> None:
        """Release everything (instance killed/retired) so the allocator's
        paired accounting closes out."""
        for aid in list(self.resident):
            self._evict(aid)
        self._queued.clear()
