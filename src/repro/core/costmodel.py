"""Roofline cost simulator — the measurement source for the latency predictor.

The container has no TPU, so this model plays the role the paper's ncu/wall
-clock profiling plays: it produces decode/prefill/finetune-unit latencies
from first principles (TPU v5e roofline + the paper's Eq. 4–5 bandwidth
-contention law) plus measurement noise. The two-stage predictor is *fit on
its samples* exactly as it would be fit on real profiles (§5, §8.8), and the
discrete-event simulator replays traces against it.

Key TPU adaptation (DESIGN.md §2): the paper's SM ratio becomes the finetune
*quantum* q_ft = k/k_max (k layer-units fused into one decode round). Round
latency under co-location follows the fused-program roofline
    T_round = max( (bytes_d + Σbytes_u) / BW_eff ,
                   (flops_d + Σflops_u) / peak_eff ) + overheads
which is linear in k in either regime — the same linearity the paper
establishes empirically (Fig. 10) and theoretically (Eq. 5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.hw import TPU_V5E, ChipSpec
from repro.models.config import ModelConfig

# Achievable-fraction constants (standard TPU engineering numbers: MXU
# efficiency on decode-shaped GEMMs, DMA streaming efficiency, and how much
# of the fused program's compute XLA actually hides under DMA).
MXU_EFF = 0.55            # effective fraction of peak FLOP/s
BW_EFF = 0.85             # effective fraction of HBM bandwidth (paper Fig. 4)
OVERLAP_EFF = 0.72        # fused-program compute/DMA overlap efficiency
STEP_OVERHEAD_S = 120e-6  # per-round dispatch/launch overhead
PER_LAYER_OVERHEAD_S = 2.2e-6
UNIT_OVERHEAD_S = 25e-6   # per finetune-unit dispatch overhead
BW_SAT_QUANTUM = 0.45     # share of chip needed to saturate HBM BW (Fig. 9)


@dataclasses.dataclass(frozen=True)
class InstanceSpec:
    """A serving/finetune deployment unit: a TP group of `tp` chips."""
    chip: ChipSpec = TPU_V5E
    tp: int = 8

    @property
    def peak_flops(self) -> float:
        return self.chip.peak_flops_bf16 * self.tp * MXU_EFF

    @property
    def hbm_bw(self) -> float:
        return self.chip.hbm_bw * self.tp * BW_EFF

    @property
    def hbm_bytes(self) -> float:
        return self.chip.hbm_bytes * self.tp

    @property
    def host_dma_bw(self) -> float:
        return self.chip.host_dma_bw * self.tp


@dataclasses.dataclass
class DecodeWork:
    """Bytes/FLOPs of one decode round."""
    bytes_hbm: float
    flops: float
    ici_s: float          # TP collective time per round


@dataclasses.dataclass
class UnitWork:
    """Bytes/FLOPs of one finetune layer-unit (fwd or bwd avg)."""
    bytes_hbm: float
    flops: float
    layer_weight_bytes: float   # for window swap timing


class CostModel:
    def __init__(self, cfg: ModelConfig, inst: InstanceSpec = InstanceSpec(),
                 noise_sigma: float = 0.015, seed: int = 0):
        self.cfg = cfg
        self.inst = inst
        self.noise_sigma = noise_sigma
        self.rng = np.random.default_rng(seed)

    # ------------------------------------------------------- workloads ----
    def decode_work(self, bs: int, mean_ctx: float) -> DecodeWork:
        cfg = self.cfg
        active = cfg.active_param_count()
        w_bytes = active * 2.0                           # bf16 weight stream
        ctx_eff = cfg.effective_cache_len(int(mean_ctx))
        kv_bytes = bs * ctx_eff * cfg.cache_bytes_per_token() \
            + bs * cfg.state_bytes()
        flops = 2.0 * active * bs \
            + 4.0 * bs * ctx_eff * len(cfg.attn_layer_indices()) \
            * cfg.num_kv_heads * cfg.head_dim * max(cfg.q_per_kv, 1)
        # TP all-reduce of (bs, d) per layer, 2x, ring over tp chips
        ar_bytes = 2 * cfg.num_layers * bs * cfg.d_model * 2.0
        link = self.inst.chip.ici_bw_per_link * max(self.inst.tp, 1)
        ici_s = 0.0 if (self.inst.tp <= 1 or link <= 0) else \
            2 * (self.inst.tp - 1) / self.inst.tp * ar_bytes / link
        return DecodeWork(bytes_hbm=w_bytes + kv_bytes, flops=flops,
                          ici_s=ici_s)

    def prefill_latency(self, prompt_len: int, bs: int = 1) -> float:
        cfg = self.cfg
        active = cfg.active_param_count()
        flops = 2.0 * active * prompt_len * bs \
            + 4.0 * prompt_len * cfg.effective_cache_len(prompt_len) / 2 \
            * len(cfg.attn_layer_indices()) * cfg.num_heads * cfg.head_dim * bs
        bytes_hbm = active * 2.0 + bs * prompt_len * cfg.d_model * 2 * 8
        return max(flops / self.inst.peak_flops,
                   bytes_hbm / self.inst.hbm_bw) + STEP_OVERHEAD_S

    def checkpoint_time(self) -> float:
        """Device->host commit of the PEFT training state: bf16 trainable
        weights plus fp32 Adam moments stream over the host DMA link (the
        frozen base weights need no commit — that is the PEFT win). The
        cluster failure layer (core/cluster.py) charges this to the
        finetune quantum budget — a round inside the commit window runs
        quantum 0, so inference latency never pays for checkpointing."""
        trainable = self.cfg.lora_param_count() or self.cfg.param_count()
        ckpt_bytes = trainable * (2.0 + 8.0)
        return ckpt_bytes / self.inst.host_dma_bw

    def adapter_load_time(self, adapter_bytes: float,
                          setup_s: float = 0.001) -> float:
        """Host->HBM hot-load of one LoRA adapter's weights (multi-tenant
        serving, core/adapters.py): the bf16 adapter tensors stream over
        the host DMA link after a fixed dispatch/registration handshake.
        Deterministic (no ``_noise()``) for the same reason as
        ``kv_migration_time``: loads land on the seeded dispatch path and
        an RNG draw here would shift every downstream stream."""
        return setup_s + adapter_bytes / self.inst.host_dma_bw

    def kv_migration_time(self, context_tokens: int, bw_bytes_per_s: float,
                          setup_s: float = 0.0) -> float:
        """Live KV transfer of one request to a peer instance over the
        interconnect: the context's KV pages plus the per-request decode
        state stream at the configured point-to-point bandwidth, after a
        fixed handshake. Deterministic (no ``_noise()``): the migration
        race against the preemption deadline must replay bit-identically
        under a seed, and adding an RNG draw here would shift every
        downstream stream of the per-instance cost models."""
        kv_bytes = context_tokens * self.cfg.cache_bytes_per_token() \
            + self.cfg.state_bytes()
        return setup_s + kv_bytes / max(bw_bytes_per_s, 1.0)

    def prefill_batch_latency(self, prompt_lens: Sequence[int]) -> float:
        """One fused prefill launch over a batch of (possibly ragged)
        prompts: token work is additive across requests, the weight stream
        and dispatch overhead are paid once — the batching win the prefill
        pool (core/prefill_pool.py) schedules for. Reduces exactly to
        ``prefill_latency(p, bs=1)`` for a single prompt."""
        if not prompt_lens:
            return 0.0
        cfg = self.cfg
        active = cfg.active_param_count()
        flops = bytes_hbm = 0.0
        for p in prompt_lens:
            flops += 2.0 * active * p \
                + 4.0 * p * cfg.effective_cache_len(p) / 2 \
                * len(cfg.attn_layer_indices()) * cfg.num_heads * cfg.head_dim
            bytes_hbm += p * cfg.d_model * 2 * 8
        bytes_hbm += active * 2.0
        return max(flops / self.inst.peak_flops,
                   bytes_hbm / self.inst.hbm_bw) + STEP_OVERHEAD_S

    def unit_work(self, micro_batch: int, seq_len: int,
                  backward: bool = False) -> UnitWork:
        """One layer fwd (bwd ≈ 2x flops: recompute + grads)."""
        cfg = self.cfg
        per_layer_params = cfg.active_param_count() / max(cfg.num_layers, 1)
        tokens = micro_batch * seq_len
        f = 2.0 * per_layer_params * tokens
        if backward:
            f *= 3.0   # recompute fwd + dx + dW(adapters)
        w_bytes = per_layer_params * 2.0
        act_bytes = 4 * tokens * cfg.d_model * 2.0
        return UnitWork(bytes_hbm=w_bytes + act_bytes, flops=f,
                        layer_weight_bytes=w_bytes)

    def avg_unit_work(self, micro_batch: int, seq_len: int) -> UnitWork:
        f = self.unit_work(micro_batch, seq_len, backward=False)
        b = self.unit_work(micro_batch, seq_len, backward=True)
        return UnitWork(bytes_hbm=(f.bytes_hbm + b.bytes_hbm) / 2,
                        flops=(f.flops + b.flops) / 2,
                        layer_weight_bytes=f.layer_weight_bytes)

    # -------------------------------------------------------- latencies ---
    def _noise(self) -> float:
        if self.noise_sigma <= 0:
            return 1.0
        return float(np.exp(self.rng.normal(0.0, self.noise_sigma)))

    def decode_solo(self, bs: int, mean_ctx: float, quantum: float = 1.0,
                    noisy: bool = True) -> float:
        """Decode-round latency with fraction `quantum` of the instance
        (paper Fig. 9: sublinear in the compute share, because decode is
        memory-bound and BW saturates below full allocation)."""
        w = self.decode_work(bs, mean_ctx)
        q = max(quantum, 1e-3)
        bw = self.inst.hbm_bw * min(1.0, q / BW_SAT_QUANTUM)
        t = max(w.bytes_hbm / bw, w.flops / (self.inst.peak_flops * q))
        t += w.ici_s + STEP_OVERHEAD_S \
            + self.cfg.num_layers * PER_LAYER_OVERHEAD_S
        return t * (self._noise() if noisy else 1.0)

    def colocated_round(self, bs: int, mean_ctx: float, k_units: int,
                        micro_batch: int, seq_len: int,
                        unit_weights_resident: bool = True,
                        noisy: bool = True) -> float:
        """Fused decode + k finetune-unit round latency (Eq. 5 analogue)."""
        d = self.decode_work(bs, mean_ctx)
        u = self.avg_unit_work(micro_batch, seq_len)
        u_bytes = u.bytes_hbm if unit_weights_resident \
            else u.bytes_hbm  # window streaming is on the host-DMA channel
        total_bytes = d.bytes_hbm + k_units * u_bytes
        total_flops = d.flops + k_units * u.flops
        t_mem = total_bytes / self.inst.hbm_bw
        t_comp = total_flops / self.inst.peak_flops
        # imperfect overlap: the fused program hides the smaller term only
        # partially under the larger one
        t = max(t_mem, t_comp) + (1.0 - OVERLAP_EFF) * min(t_mem, t_comp)
        t += d.ici_s + STEP_OVERHEAD_S \
            + self.cfg.num_layers * PER_LAYER_OVERHEAD_S \
            + k_units * UNIT_OVERHEAD_S
        return t * (self._noise() if noisy else 1.0)

    def chunk_work(self, chunk_tokens: int, chunk_ctx: float) -> DecodeWork:
        """Bytes/FLOPs of a prefill chunk processed inside a decode round
        (chunked prefill, FlexLLM-style token-level co-serving). Token work
        mirrors ``prefill_latency``: dense FLOPs per token plus attention of
        the chunk against the ``chunk_ctx`` tokens already resident (cached
        prefix + previously prefilled chunks). The weight stream is NOT
        charged here — the fused round pays it once via the decode side."""
        cfg = self.cfg
        active = cfg.active_param_count()
        flops = 2.0 * active * chunk_tokens \
            + 4.0 * chunk_tokens * cfg.effective_cache_len(
                int(chunk_ctx + chunk_tokens / 2)) \
            * len(cfg.attn_layer_indices()) * cfg.num_heads * cfg.head_dim
        bytes_hbm = chunk_tokens * cfg.d_model * 2 * 8
        return DecodeWork(bytes_hbm=bytes_hbm, flops=flops, ici_s=0.0)

    def mixed_round_latency(self, bs: int, mean_ctx: float,
                            chunk_tokens: int, chunk_ctx: float = 0.0,
                            k_units: int = 0, micro_batch: int = 2,
                            seq_len: int = 1024,
                            noisy: bool = True) -> float:
        """One decode round with ``chunk_tokens`` of prefill work mixed in
        (prefill_mode="chunked"): decode token work, the prefill chunk and
        optionally k finetune units share one fused launch. The weight
        stream and dispatch overhead are paid once — the chunk piggybacks
        on decode's memory traffic and fills its idle compute, which is the
        chunked-prefill win; the cost is the chunk's FLOPs landing on the
        round's critical path (the TPOT impact the predictor prices).
        ``bs == 0`` models a prefill-only round (weight stream still paid).
        Reduces to ``colocated_round``/``decode_solo`` at chunk_tokens=0."""
        d = self.decode_work(bs, mean_ctx) if bs > 0 else DecodeWork(
            bytes_hbm=self.cfg.active_param_count() * 2.0, flops=0.0,
            ici_s=0.0)
        c = self.chunk_work(chunk_tokens, chunk_ctx) if chunk_tokens > 0 \
            else DecodeWork(0.0, 0.0, 0.0)
        total_bytes = d.bytes_hbm + c.bytes_hbm
        total_flops = d.flops + c.flops
        if k_units > 0:
            u = self.avg_unit_work(micro_batch, seq_len)
            total_bytes += k_units * u.bytes_hbm
            total_flops += k_units * u.flops
        t_mem = total_bytes / self.inst.hbm_bw
        t_comp = total_flops / self.inst.peak_flops
        t = max(t_mem, t_comp) + (1.0 - OVERLAP_EFF) * min(t_mem, t_comp)
        t += d.ici_s + STEP_OVERHEAD_S \
            + self.cfg.num_layers * PER_LAYER_OVERHEAD_S \
            + k_units * UNIT_OVERHEAD_S
        return t * (self._noise() if noisy else 1.0)

    def unit_solo(self, micro_batch: int, seq_len: int,
                  backward: bool = False, noisy: bool = True) -> float:
        u = self.unit_work(micro_batch, seq_len, backward)
        t = max(u.bytes_hbm / self.inst.hbm_bw,
                u.flops / self.inst.peak_flops) + UNIT_OVERHEAD_S
        return t * (self._noise() if noisy else 1.0)

    def layer_swap_time(self, micro_batch: int, seq_len: int) -> float:
        """Host->HBM streaming of one layer's frozen weights (window swap)."""
        u = self.unit_work(micro_batch, seq_len)
        return u.layer_weight_bytes / self.inst.host_dma_bw

    # --------------------------------------------------------- utilization
    def decode_utilization(self, bs: int, mean_ctx: float):
        """(sm_util, bw_util) of a solo decode round — paper Fig. 4."""
        w = self.decode_work(bs, mean_ctx)
        t = self.decode_solo(bs, mean_ctx, noisy=False)
        bw_util = w.bytes_hbm / (t * self.inst.chip.hbm_bw * self.inst.tp)
        sm_util = w.flops / (t * self.inst.chip.peak_flops_bf16 * self.inst.tp)
        return sm_util, bw_util
