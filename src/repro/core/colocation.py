"""Colocated step builder — the TPU analogue of GreenContext SM partitioning.

One jitted XLA program per quantum level k fuses the decode step with k
finetune layer-units. Inside a single program, XLA's scheduler interleaves
the finetune matmuls (MXU-bound) with decode's weight/KV streaming
(DMA-bound) — temporal multiplexing of the same resources the paper splits
spatially. The scheduler dispatches among the precompiled variants each
round, which is the preemption mechanism: k=0 *is* "inference preempts all".

Correctness invariant (tested): running the fused program must be bit-
equivalent to running decode_step and k unit_steps separately.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.training import peft as P


class ColocatedRunner:
    """Holds the per-quantum compiled variants for one (decode, finetune)
    pair on one instance."""

    def __init__(self, cfg_inf: ModelConfig, params_inf,
                 cfg_ft: ModelConfig, params_ft, pc: P.PeftConfig,
                 k_max: int = 10, use_kernels: bool = False,
                 donate: bool = True):
        self.cfg_inf = cfg_inf
        self.cfg_ft = cfg_ft
        self.k_max = k_max
        self.unit_step = P.make_unit_step(cfg_ft, pc, params_ft)
        self._params_inf = params_inf
        self._use_kernels = use_kernels
        self._variants: Dict[int, Callable] = {}
        self._donate = donate

    def _build(self, k: int) -> Callable:
        cfg = self.cfg_inf
        params = self._params_inf
        unit_step = self.unit_step
        use_kernels = self._use_kernels

        def step(tokens, positions, cache, ft_state):
            logits, cache = MD.decode_step(params, cfg, tokens, positions,
                                           cache, use_kernels=use_kernels)
            ft_state = P.run_units(unit_step, ft_state, k)
            return logits, cache, ft_state

        donate = (2, 3) if self._donate else ()
        return jax.jit(step, donate_argnums=donate)

    def variant(self, k: int) -> Callable:
        k = max(0, min(k, self.k_max))
        if k not in self._variants:
            self._variants[k] = self._build(k)
        return self._variants[k]

    def run_round(self, k: int, tokens, positions, cache, ft_state):
        return self.variant(k)(tokens, positions, cache, ft_state)

    def precompile(self, tokens, positions, cache, ft_state,
                   ks: Optional[list] = None) -> None:
        """AOT-lower all quantum variants (startup, off the critical path)."""
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            (tokens, positions, cache, ft_state))
        for k in (ks if ks is not None else range(self.k_max + 1)):
            self.variant(k).lower(*shapes).compile()


def make_ft_only_step(cfg_ft: ModelConfig, params_ft, pc: P.PeftConfig,
                      units: int):
    """Free-running finetune burst (bs=0 rounds / SeparateMode instance)."""
    unit_step = P.make_unit_step(cfg_ft, pc, params_ft)

    @jax.jit
    def burst(ft_state):
        return P.run_units(unit_step, ft_state, units)

    return burst
