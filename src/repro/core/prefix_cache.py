"""Per-instance prefix cache: modeled KV reuse for cache-aware routing.

PR 4's version of this module was a session-keyed LRU — an instance that
already held a session's prompt KV could skip prefill for the cached
prefix. PR 10 replaces the engine with a cross-session **radix prefix
tree** (``core/prefix_tree.py``, RadixAttention-style): prompts are
ordered ``(segment_id, n_tokens)`` runs, so *different* sessions that
share a leading segment (a per-tenant system prompt, a few-shot header —
the ``shared_prefix`` trace scenario) hit each other's cached KV.
``PrefixCache`` survives as a thin adapter that keeps the PR 4 public
API and stats, and — crucially — reproduces the old LRU *bit-exactly*
for session-keyed traffic: a request without ``prefix_segments`` maps to
a single-run path keyed by its session, which the tree stores as one
node with whole-entry LRU eviction, i.e. exactly the old OrderedDict.

Capacity is still real memory: construction reserves whole chunks from
the instance's ``UnifiedAllocator`` reusable pool (``prefix_reserve``),
which shrinks both the finetune window's capacity and the instance's KV
admission budget — a bigger cache trades decode/finetune headroom for
TTFT, it is not free.

Everything is deterministic (plain dict/tree state, no RNG), so cluster
runs stay bit-reproducible for a fixed seed (tested).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.allocator import UnifiedAllocator
from repro.core.prefix_tree import (
    RadixPrefixTree,
    Segments,
    normalize_segments,
    session_segments,
)


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    chunks: int = 16               # capacity asked from the unified pool
    min_hit_tokens: int = 32       # ignore hits too small to matter
    cross_session: bool = True     # honor prefix_segments (False = the
    #                                PR 4 session-keyed baseline, used as
    #                                the no-sharing arm in benchmarks)


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0               # dispatch-time lookups only
    hits: int = 0
    misses: int = 0
    hit_tokens: int = 0            # prefill tokens saved, summed
    shared_hit_tokens: int = 0     # subset of hit_tokens matched on a
    #                                non-terminal run, i.e. KV another
    #                                session (or turn-prefix) cached
    insertions: int = 0
    evictions: int = 0             # nodes evicted (== sessions for
    #                                session-keyed traffic)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class PrefixCache:
    """Radix-tree prefix cache for one instance, PR 4-compatible API.

    ``lookup`` is called by the router at dispatch time (the instance is
    chosen first, then its cache is consulted); ``insert`` is called by
    the instance when a request's prompt KV becomes resident at decode
    admission. A session moved to another instance (affinity overflow)
    simply goes cold here and warms up there — LRU ages it out, but its
    *shared* leading segments stay hot as long as any session uses them.
    """

    def __init__(self, cfg: PrefixCacheConfig, alloc: UnifiedAllocator):
        self.cfg = cfg
        self.granted_chunks = alloc.prefix_reserve(max(cfg.chunks, 0))
        self.capacity_tokens = self.granted_chunks * alloc.tokens_per_chunk
        self.tree = RadixPrefixTree(self.capacity_tokens)
        self.stats = PrefixCacheStats()

    # ------------------------------------------------------------ paths --
    def _path(self, session_id: int, total_tokens: int,
              segments: Segments) -> Segments:
        if segments and self.cfg.cross_session:
            return normalize_segments(segments)
        return session_segments(session_id, total_tokens)

    # ---------------------------------------------------------- queries --
    def lookup(self, session_id: int, prompt_len: int,
               segments: Segments = ()) -> int:
        """Tokens of ``prompt_len`` covered by the cached tree (0 on
        miss). A hit refreshes the matched path's LRU position. At least
        one token always remains to prefill — the new turn's tokens are
        never cached. The hit itself is ``peek``'s computation, so a
        routing decision made on a peek is granted exactly what it saw."""
        self.stats.lookups += 1
        hit, shared, path = self._probe(session_id, prompt_len, segments)
        if hit == 0:
            self.stats.misses += 1
            return 0
        self.tree.touch(path)
        self.stats.hits += 1
        self.stats.hit_tokens += hit
        self.stats.shared_hit_tokens += shared
        return hit

    def peek(self, session_id: int, prompt_len: int,
             segments: Segments = ()) -> int:
        """Non-mutating ``lookup``: same hit computation (min-hit floor,
        last token never covered) but no stats and no LRU refresh — the
        probe cross-instance cache-aware routing uses to compare every
        candidate's cache before committing to one (whose ``lookup`` then
        grants exactly the peeked credit)."""
        hit, _, _ = self._probe(session_id, prompt_len, segments)
        return hit

    def _probe(self, session_id: int, prompt_len: int,
               segments: Segments) -> Tuple[int, int, Segments]:
        path = self._path(session_id, prompt_len, segments)
        total, final_run = self.tree.match(path)
        hit = min(total, prompt_len - 1)
        if hit < self.cfg.min_hit_tokens:
            return 0, 0, path
        shared = max(min(total - final_run, hit), 0)
        return hit, shared, path

    def revoke(self, hit_tokens: int) -> None:
        """Reverse one granted hit's accounting (the router calls this
        when a pooled-mode pin breaks after prefill already ran short):
        the saved tokens were spent, but the hit must not count as a
        cache win. Grant and revoke bookkeeping both live here. The
        shared-token split is left as granted — it describes what the
        tree matched, not what the request ultimately saved."""
        self.stats.hits -= 1
        self.stats.misses += 1
        self.stats.hit_tokens -= hit_tokens

    # ---------------------------------------------------------- updates --
    def insert(self, session_id: int, prefix_tokens: int,
               segments: Segments = ()) -> None:
        """Record that this request's prompt KV (``prefix_tokens``) is
        now resident, evicting least-recently-used tree leaves past
        capacity."""
        if self.capacity_tokens <= 0 or prefix_tokens <= 0:
            return
        self.tree.insert(self._path(session_id, prefix_tokens, segments))
        self.stats.insertions += 1
        self._sync_evictions()

    def _sync_evictions(self) -> None:
        if self.tree.evicted_nodes:
            self.stats.evictions += self.tree.evicted_nodes
            self.tree.evicted_nodes = 0

    def invalidate_all(self) -> None:
        """Drop every cached prefix at once — the instance's KV memory is
        gone (host failure, cluster failure layer). The cache object stays
        alive: ``revoke`` must still work for in-flight requests whose pin
        to this instance breaks after the kill. Flushed nodes count as
        evictions in the stats."""
        self.stats.evictions += self.tree.clear()

    # ------------------------------------------------------- inspection --
    def __len__(self) -> int:
        return len(self.tree)

    @property
    def used_tokens(self) -> int:
        return self.tree.used_tokens

    def check_invariants(self) -> None:
        self.tree.check_invariants()
