"""Per-instance session prefix cache: modeled KV reuse for sticky routing.

PR 3's ``session_affinity`` policy was routing-only — the sticky placement
existed, but nothing made it *worth* anything. This module models the thing
stickiness buys: an instance that already holds a session's prompt KV can
skip prefill for the cached prefix, so a sticky hit shortens the request's
effective prefill (``Request.effective_prompt_len``) and the policy's win
shows up in TTFT, not just placement stability (SGLang's RadixAttention and
vLLM's prefix caching are the production analogues).

The cache is an LRU over sessions, capacity in tokens. Capacity is real
memory: construction reserves whole chunks from the instance's
``UnifiedAllocator`` reusable pool (``prefix_reserve``), which shrinks both
the finetune window's capacity and the instance's KV admission budget — a
bigger cache trades decode/finetune headroom for TTFT, it is not free.

Everything is deterministic (plain dict/OrderedDict state, no RNG), so
cluster runs stay bit-reproducible for a fixed seed (tested).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

from repro.core.allocator import UnifiedAllocator


@dataclasses.dataclass(frozen=True)
class PrefixCacheConfig:
    chunks: int = 16               # capacity asked from the unified pool
    min_hit_tokens: int = 32       # ignore hits too small to matter


@dataclasses.dataclass
class PrefixCacheStats:
    lookups: int = 0               # session-keyed lookups only
    hits: int = 0
    misses: int = 0
    hit_tokens: int = 0            # prefill tokens saved, summed
    insertions: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


class PrefixCache:
    """LRU of ``session_id -> cached prefix tokens`` for one instance.

    ``lookup`` is called by the router at dispatch time (the instance is
    chosen first, then its cache is consulted); ``insert`` is called by the
    instance when a request's prompt KV becomes resident at decode
    admission. A session moved to another instance (affinity overflow)
    simply goes cold here and warms up there — the LRU ages it out.
    """

    def __init__(self, cfg: PrefixCacheConfig, alloc: UnifiedAllocator):
        self.cfg = cfg
        self.granted_chunks = alloc.prefix_reserve(max(cfg.chunks, 0))
        self.capacity_tokens = self.granted_chunks * alloc.tokens_per_chunk
        self._entries: "OrderedDict[int, int]" = OrderedDict()
        self._used_tokens = 0
        self.stats = PrefixCacheStats()

    def lookup(self, session_id: int, prompt_len: int) -> int:
        """Tokens of ``prompt_len`` covered by this session's cached prefix
        (0 on miss). A hit refreshes the entry's LRU position. At least one
        token always remains to prefill — the new turn's tokens are never
        cached. The hit itself is ``peek``'s computation, so a routing
        decision made on a peek is granted exactly what it saw."""
        self.stats.lookups += 1
        hit = self.peek(session_id, prompt_len)
        if hit == 0:
            self.stats.misses += 1
            return 0
        self._entries.move_to_end(session_id)
        self.stats.hits += 1
        self.stats.hit_tokens += hit
        return hit

    def peek(self, session_id: int, prompt_len: int) -> int:
        """Non-mutating ``lookup``: same hit computation (min-hit floor,
        last token never covered) but no stats and no LRU refresh — the
        probe cross-instance cache-aware routing uses to compare every
        candidate's cache before committing to one (whose ``lookup`` then
        grants exactly the peeked credit)."""
        cached = self._entries.get(session_id)
        hit = min(cached, prompt_len - 1) if cached is not None else 0
        return hit if hit >= self.cfg.min_hit_tokens else 0

    def revoke(self, hit_tokens: int) -> None:
        """Reverse one granted hit's accounting (the router calls this
        when a pooled-mode pin breaks after prefill already ran short):
        the saved tokens were spent, but the hit must not count as a
        cache win. Grant and revoke bookkeeping both live here."""
        self.stats.hits -= 1
        self.stats.misses += 1
        self.stats.hit_tokens -= hit_tokens

    def insert(self, session_id: int, prefix_tokens: int) -> None:
        """Record that this session's prompt KV (``prefix_tokens``) is now
        resident, evicting least-recently-used sessions past capacity."""
        if self.capacity_tokens <= 0 or prefix_tokens <= 0:
            return
        prefix_tokens = min(prefix_tokens, self.capacity_tokens)
        old = self._entries.pop(session_id, 0)
        self._used_tokens -= old
        self._entries[session_id] = prefix_tokens
        self._used_tokens += prefix_tokens
        self.stats.insertions += 1
        while self._used_tokens > self.capacity_tokens:
            _, tok = self._entries.popitem(last=False)
            self._used_tokens -= tok
            self.stats.evictions += 1

    def invalidate_all(self) -> None:
        """Drop every cached prefix at once — the instance's KV memory is
        gone (host failure, cluster failure layer). The cache object stays
        alive: ``revoke`` must still work for in-flight requests whose pin
        to this instance breaks after the kill. Flushed entries count as
        evictions in the stats."""
        self.stats.evictions += len(self._entries)
        self._entries.clear()
        self._used_tokens = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def used_tokens(self) -> int:
        return self._used_tokens

    def check_invariants(self) -> None:
        assert self._used_tokens == sum(self._entries.values())
        assert self._used_tokens <= max(self.capacity_tokens, 0)
