"""Cross-session radix prefix tree: the engine behind ``PrefixCache``.

The simulator has no real token content, so token identity is symbolic:
a prompt is an ordered tuple of ``(segment_id, n_tokens)`` runs
(``Request.prefix_segments``). Two prompts share a prefix exactly while
they consume the same segment ids with full-length matches, diverging
mid-segment at the shorter length — the same structure RadixAttention
(SGLang) exploits on real token ids. A session-keyed trace degenerates
to one run per session (``SESSION_SEG_BASE + session_id``), which is how
the ``PrefixCache`` adapter reproduces the PR 4 LRU bit-identically; the
``shared_prefix`` scenario layers a per-tenant system-prompt segment
under the session run, so *different* sessions hit each other's cached
system prompts.

Tree semantics (chosen so the single-run path is exactly the old LRU):

  * **match** walks the query runs, crediting ``min(edge, run)`` tokens
    and stopping at the first divergence. Non-mutating.
  * **insert** stores the path with *terminal-replace* semantics: the
    inserted path's total length becomes exactly the stored length for
    that chain (a shorter re-insert truncates, dropping anything beyond
    — the pop-old/set-new behaviour of the LRU), while interior shared
    segments split radix-style so sibling branches survive.
  * **eviction is node-granular LRU**: every insert/hit refreshes the
    whole matched path, and capacity pressure evicts the
    least-recently-used *leaf* — so a hot shared system-prompt node
    stays resident while the cold session tails under it age out.

Determinism: plain dict state, a monotone touch clock, no RNG — cluster
runs stay bit-reproducible for a fixed seed (tested).

``digest(k)`` summarizes the tree for the gossip plane (core/gossip.py):
the top-k prefix paths by cached tokens, each as a stable 64-bit FNV-1a
fingerprint over the (collapsed) segment-id path plus the cached token
count along it. ``path_fingerprints`` computes the matching query-side
fingerprints, so a router can estimate a hit from the digest alone —
zero synchronous peeks.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from repro.serving.request import GROUP_SEG_BASE  # noqa: F401  (re-export)
from repro.serving.request import SESSION_SEG_BASE

Segments = Tuple[Tuple[int, int], ...]

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def _fnv_step(fp: int, seg_id: int) -> int:
    """One 64-bit FNV-1a step folding ``seg_id`` into a path fingerprint.
    Deterministic across processes (unlike ``hash``) and cheap."""
    for shift in (0, 8, 16, 24, 32, 40, 48, 56):
        fp = ((fp ^ ((seg_id >> shift) & 0xFF)) * _FNV_PRIME) & _MASK64
    return fp


def path_fingerprints(segments: Segments) -> List[Tuple[int, int]]:
    """Query-side digest keys: for every cumulative run prefix of
    ``segments``, the (fingerprint, cumulative_tokens) pair — ordered
    shallowest first. Matches ``RadixPrefixTree.digest`` keys by
    construction (both collapse consecutive duplicate segment ids)."""
    out: List[Tuple[int, int]] = []
    fp, cum, prev = _FNV_OFFSET, 0, None
    for sid, n in segments:
        if n <= 0:
            continue
        cum += n
        if sid != prev:
            fp = _fnv_step(fp, sid)
            prev = sid
            out.append((fp, cum))
        else:
            out[-1] = (fp, cum)
    return out


def session_segments(session_id: int, prompt_len: int) -> Segments:
    """The single-run path a session-keyed (segment-less) request maps
    to — the degenerate tree shape that reproduces the PR 4 LRU."""
    return ((SESSION_SEG_BASE + session_id, prompt_len),)


class _Node:
    __slots__ = ("seg_id", "length", "children", "parent", "last_use")

    def __init__(self, seg_id: int, length: int, parent: "_Node"):
        self.seg_id = seg_id
        self.length = length
        self.children: Dict[int, _Node] = {}
        self.parent = parent
        self.last_use = 0


class RadixPrefixTree:
    """Radix tree over symbolic ``(segment_id, n_tokens)`` runs with
    node-granular LRU eviction under a token capacity."""

    def __init__(self, capacity_tokens: int):
        self.capacity_tokens = max(capacity_tokens, 0)
        self.root = _Node(-1, 0, None)   # sentinel, never evicted
        self.used_tokens = 0
        self.node_count = 0
        self.evicted_nodes = 0
        self._clock = 0

    # ------------------------------------------------------------ match --
    def match(self, segments: Segments) -> Tuple[int, int]:
        """Tokens of ``segments`` covered by the cached tree, walked from
        the root to the first divergence. Returns ``(matched_total,
        matched_on_final_run)`` — the difference is the shared-prefix
        share (tokens matched on non-terminal runs, e.g. a system prompt
        another session inserted). Non-mutating."""
        total = 0
        final_run = 0
        cur = self.root
        last = len(segments) - 1
        for i, (sid, n) in enumerate(segments):
            rem = n
            while rem > 0:
                child = cur.children.get(sid)
                if child is None:
                    return total, final_run
                take = min(child.length, rem)
                total += take
                if i == last:
                    final_run += take
                if child.length > rem:
                    # the edge extends beyond the query run: the stored
                    # content diverges past here, stop
                    return total, final_run
                rem -= child.length
                cur = child
        return total, final_run

    def touch(self, segments: Segments) -> None:
        """Refresh the LRU clock of every node on the matched path (the
        hit-side analogue of the LRU's ``move_to_end``)."""
        self._clock += 1
        cur = self.root
        for sid, n in segments:
            rem = n
            while rem > 0:
                child = cur.children.get(sid)
                if child is None:
                    return
                child.last_use = self._clock
                if child.length > rem:
                    return
                rem -= child.length
                cur = child

    # ----------------------------------------------------------- insert --
    def insert(self, segments: Segments) -> None:
        """Store the path with terminal-replace semantics (module
        docstring), refresh its LRU recency, then evict LRU leaves while
        over capacity. The inserted path itself is clamped to capacity
        (truncated from the tail) so it always fits."""
        if self.capacity_tokens <= 0:
            return
        segments = self._clamp(segments)
        if not segments:
            return
        self._clock += 1
        cur = self.root
        last = len(segments) - 1
        for i, (sid, n) in enumerate(segments):
            final = i == last
            rem = n
            while rem > 0:
                child = cur.children.get(sid)
                if child is None:
                    child = _Node(sid, rem, cur)
                    cur.children[sid] = child
                    self.used_tokens += rem
                    self.node_count += 1
                    rem = 0
                elif child.length <= rem:
                    if final and not child.children:
                        # grow the terminal edge in place: a chain with
                        # no branches stays ONE node, which is what makes
                        # the single-run (session-keyed) path reproduce
                        # the LRU's pop-old/set-new + whole-entry
                        # eviction exactly
                        self.used_tokens += rem - child.length
                        child.length = rem
                        rem = 0
                    else:
                        rem -= child.length
                        cur = child
                        cur.last_use = self._clock
                        continue
                elif final:
                    # shorter re-insert of this chain: truncate the edge
                    # and drop everything beyond (LRU pop-old/set-new)
                    self.used_tokens -= child.length - rem
                    child.length = rem
                    self._drop_subtree(child, count_evictions=True)
                    rem = 0
                else:
                    # interior divergence mid-edge: radix split so the
                    # existing continuation (and its branches) survive
                    self._split(child, rem)
                    rem = 0
                cur = child
                cur.last_use = self._clock
            if final:
                # terminal-replace: the stored chain ends exactly here
                self._drop_subtree(cur, count_evictions=True)
        while self.used_tokens > self.capacity_tokens:
            victim = self._lru_leaf()
            if victim is None:       # only the just-inserted path remains
                break
            self._evict(victim)

    def _clamp(self, segments: Segments) -> Segments:
        total = sum(n for _, n in segments if n > 0)
        budget = self.capacity_tokens
        if total <= budget:
            return tuple((s, n) for s, n in segments if n > 0)
        out: List[Tuple[int, int]] = []
        for sid, n in segments:
            if n <= 0 or budget <= 0:
                break
            take = min(n, budget)
            out.append((sid, take))
            budget -= take
        return tuple(out)

    def _split(self, node: _Node, at: int) -> None:
        """Split ``node``'s edge at ``at`` tokens: the top keeps the
        parent link, a same-seg continuation child inherits the rest and
        the original children. Token totals are unchanged."""
        cont = _Node(node.seg_id, node.length - at, node)
        cont.children = node.children
        for ch in cont.children.values():
            ch.parent = cont
        cont.last_use = node.last_use
        node.children = {node.seg_id: cont}
        node.length = at
        self.node_count += 1

    def _drop_subtree(self, node: _Node, count_evictions: bool) -> int:
        """Remove every descendant of ``node`` (not the node itself)."""
        dropped = 0
        stack = list(node.children.values())
        node.children = {}
        while stack:
            n = stack.pop()
            self.used_tokens -= n.length
            self.node_count -= 1
            dropped += 1
            stack.extend(n.children.values())
        if count_evictions:
            self.evicted_nodes += dropped
        return dropped

    # --------------------------------------------------------- eviction --
    def _lru_leaf(self) -> Optional[_Node]:
        """The least-recently-used evictable leaf (ties impossible: the
        touch clock is strictly monotone). The most recently touched
        path is visited last by construction, so the just-inserted
        terminal is only ever returned when it is the sole leaf left —
        and the insert-time clamp guarantees that case fits."""
        best: Optional[_Node] = None
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif best is None or n.last_use < best.last_use:
                best = n
        if best is not None and best.last_use == self._clock \
                and len(self.root.children) == 1 \
                and self.node_count == self._path_len(best):
            return None
        return best

    def _path_len(self, node: _Node) -> int:
        n = 0
        while node is not None and node is not self.root:
            n += 1
            node = node.parent
        return n

    def _evict(self, node: _Node) -> None:
        assert not node.children
        self.used_tokens -= node.length
        self.node_count -= 1
        self.evicted_nodes += 1
        del node.parent.children[node.seg_id]

    def clear(self) -> int:
        """Drop everything (instance KV loss). Returns nodes dropped."""
        n = self._drop_subtree(self.root, count_evictions=False)
        self.used_tokens = 0
        return n

    # ----------------------------------------------------------- digest --
    def digest(self, k: int) -> Tuple[Tuple[int, int], ...]:
        """Top-``k`` cached prefix paths by token mass, as
        ``(fingerprint, cached_tokens)`` pairs sorted heaviest first
        (fingerprint ascending on ties, so the digest is deterministic).
        Fingerprints collapse same-seg continuation edges, matching
        ``path_fingerprints`` on the query side; a collapsed path keeps
        its deepest (largest) token count."""
        by_fp: Dict[int, int] = {}
        stack = [(child, _FNV_OFFSET, 0, -1)
                 for child in self.root.children.values()]
        while stack:
            node, fp, cum, prev_sid = stack.pop()
            if node.seg_id != prev_sid:
                fp = _fnv_step(fp, node.seg_id)
            cum += node.length
            if cum > by_fp.get(fp, 0):
                by_fp[fp] = cum
            for ch in node.children.values():
                stack.append((ch, fp, cum, node.seg_id))
        top = sorted(by_fp.items(), key=lambda e: (-e[1], e[0]))
        return tuple(top[:max(k, 0)])

    # ------------------------------------------------------- invariants --
    def __len__(self) -> int:
        return self.node_count

    def check_invariants(self) -> None:
        total, count = 0, 0
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            assert n.length > 0, "zero-length node"
            assert n.parent.children.get(n.seg_id) is n, "broken parent link"
            total += n.length
            count += 1
            stack.extend(n.children.values())
        assert total == self.used_tokens, \
            (total, self.used_tokens)
        assert count == self.node_count
        assert self.used_tokens <= max(self.capacity_tokens, 0)


def normalize_segments(segments: Iterable[Tuple[int, int]]) -> Segments:
    """Drop empty runs and merge consecutive runs with the same segment
    id (the tree and fingerprints assume adjacent runs differ)."""
    out: List[Tuple[int, int]] = []
    for sid, n in segments:
        if n <= 0:
            continue
        if out and out[-1][0] == sid:
            out[-1] = (sid, out[-1][1] + n)
        else:
            out.append((sid, n))
    return tuple(out)
