"""First-class control-plane API: policy interfaces + a string-keyed
registry.

Harli's contribution is a *composition* of interchangeable mechanisms —
routing, prefill placement, QoS-guaranteed scaling — and every future
scenario on the ROADMAP (heterogeneous fleets, multi-tenant finetune
queues, cross-instance cache-aware placement) is a new *policy* over the
same mechanism. Before this module, each choice was a string-enum
``if/elif`` chain inside ``router.py`` / ``cluster.py`` /
``autoscaler.py``; adding a policy meant editing three core modules. Now
the core modules own only mechanism (queues, hand-off, accounting,
cooldowns, decision logs) and decisions live in self-contained policy
classes registered by name:

    from repro.core.api import RoutingPolicy, register_policy

    @register_policy("my_policy")
    class MyPolicy(RoutingPolicy):
        def pick(self, cand, req, router):
            return min(cand, key=lambda i: (i.load(), i.inst_id))

Nothing else changes: ``RouterConfig(policy="my_policy")`` now resolves
through the registry, every entry point (``ExperimentSpec``,
``examples/cluster_sim.py``, the benchmarks) accepts the new name, and
the router's dispatch path needs no edits. ``cache_aware`` routing
(core/policies/cache_aware.py) is the worked proof — see docs/api.md.

Three policy kinds:

  * ``routing``  — ``RoutingPolicy``: which decode instance gets a
    request. Owns its own state (RNG, round-robin cursor, sticky-session
    map, admission pins).
  * ``prefill``  — ``PrefillPlacement``: where prefill work runs
    (chained / pooled / chunked deployment modes). One object is shared
    by the router (placement of each request) and the cluster loop
    (tier scaling, timelines, result accounting).
  * ``scaling``  — ``ScalingPolicy``: pure decision functions for the
    autoscaler's control loops (decode fleet, pooled prefill tier,
    chunked budget). The ``Autoscaler`` keeps cooldown bookkeeping and
    the decision log; policies only decide.
  * ``adapter_placement`` — ``AdapterPlacement``: which decode instance
    serves an adapter-carrying request (multi-LoRA serving,
    core/adapters.py). Consulted by the router *instead of* the routing
    policy when the request carries an ``adapter_id`` and adapter
    serving is enabled.

``ExperimentSpec`` (core/experiment.py) is re-exported here lazily so
``from repro.core.api import ExperimentSpec`` works without an import
cycle (experiment.py composes the modules that import this one).
"""

from __future__ import annotations

import abc
from typing import Dict, List, Optional, Tuple, Type

# Router dispatch sentinels (canonical home; core/router.py re-exports
# them for back compatibility).
PENDING = -2     # admitted; still in the prefill stage
REJECTED = -1

KINDS = ("routing", "prefill", "scaling", "migration", "adapter_placement")


def _check_kind(kind: str) -> None:
    """Unknown *kinds* are a programming error distinct from unknown
    names; fail loudly with the kind list (never another kind's names)."""
    if kind not in KINDS:
        raise ValueError(
            f"unknown policy kind {kind!r}; valid kinds: {', '.join(KINDS)}")


class PolicyNotFoundError(KeyError):
    """Unknown policy name. The message lists what IS registered *for the
    requested kind only* so a typo'd spec/CLI run fails with the fix in
    the error text (suggestions from other kinds would be noise)."""

    def __init__(self, kind: str, name: str, available: Tuple[str, ...]):
        self.kind = kind
        self.name = name
        self.available = available
        super().__init__(
            f"unknown {kind} policy {name!r}; registered {kind} policies: "
            f"{', '.join(available) or '(none)'}")

    def __str__(self) -> str:  # KeyError str() adds quotes; keep it clean
        return self.args[0]


class PolicyRegistry:
    """String-keyed registry, one namespace per policy kind."""

    def __init__(self):
        self._by_kind: Dict[str, Dict[str, type]] = {k: {} for k in KINDS}

    def register(self, kind: str, name: str, cls: type) -> None:
        _check_kind(kind)
        existing = self._by_kind[kind].get(name)
        if existing is not None and existing is not cls:
            raise ValueError(
                f"{kind} policy {name!r} already registered by "
                f"{existing.__module__}.{existing.__qualname__}")
        self._by_kind[kind][name] = cls

    def resolve(self, kind: str, name: str) -> type:
        _check_kind(kind)
        self._ensure_builtins()
        try:
            return self._by_kind[kind][name]
        except KeyError:
            # suggestion list scoped to the requested kind only
            raise PolicyNotFoundError(kind, name, self.names(kind)) from None

    def names(self, kind: str) -> Tuple[str, ...]:
        _check_kind(kind)
        self._ensure_builtins()
        return tuple(sorted(self._by_kind[kind]))

    @staticmethod
    def _ensure_builtins() -> None:
        # the built-in policies live in repro.core.policies and register on
        # import; resolve/names pull them in lazily so a bare
        # ``ClusterRouter(...)`` works without anyone importing the package
        import repro.core.policies  # noqa: F401  (side-effect: registration)


REGISTRY = PolicyRegistry()


def _infer_kind(cls: type) -> str:
    if issubclass(cls, RoutingPolicy):
        return "routing"
    if issubclass(cls, PrefillPlacement):
        return "prefill"
    if issubclass(cls, ScalingPolicy):
        return "scaling"
    if issubclass(cls, MigrationPolicy):
        return "migration"
    if issubclass(cls, AdapterPlacement):
        return "adapter_placement"
    raise TypeError(
        f"{cls.__qualname__} subclasses none of RoutingPolicy / "
        f"PrefillPlacement / ScalingPolicy / MigrationPolicy / "
        f"AdapterPlacement; pass kind= explicitly")


def register_policy(name: str, *, kind: Optional[str] = None):
    """Class decorator: ``@register_policy("session_affinity")``. The
    policy kind is inferred from the base class (or given explicitly);
    the class gains a ``name`` attribute and becomes resolvable through
    ``RouterConfig.policy`` / ``ClusterConfig.prefill_mode`` /
    ``AutoscalerConfig.*_policy`` and ``ExperimentSpec``."""
    def deco(cls):
        REGISTRY.register(kind or _infer_kind(cls), name, cls)
        cls.name = name
        return cls
    return deco


def resolve_policy(kind: str, name: str) -> type:
    """Public lookup: registry class for ``name``, raising
    ``PolicyNotFoundError`` (with the registered names in the message)
    when unknown."""
    return REGISTRY.resolve(kind, name)


def available_policies(kind: str) -> Tuple[str, ...]:
    return REGISTRY.names(kind)


# --------------------------------------------------------------- routing --
class RoutingPolicy(abc.ABC):
    """Decode-stage placement decision. Instantiated once per
    ``ClusterRouter`` with the router's config; any decision state (RNG,
    cursors, sticky maps, pins) belongs to the policy object, so the
    router stays pure mechanism.

    ``router`` in the hooks is the owning ``ClusterRouter`` — policies
    may read fleet state (``router.instances``, ``router.predictor``)
    but must not mutate it."""

    name: str = ""
    # declare True when the policy keys on Request.session_id (sticky /
    # cache-style policies): entry points that generate the trace consult
    # this to default sessions on, instead of hardcoding policy names
    needs_sessions: bool = False

    def __init__(self, cfg):
        self.cfg = cfg               # RouterConfig

    @abc.abstractmethod
    def pick(self, cand: List, req, router):
        """Choose one instance from the non-empty candidate list for
        ``req``. Must be deterministic given the policy's own state."""

    def pin_for_prefill(self, cand: List, req, router):
        """Pooled-mode hook, called at admission (before prefill runs):
        return the decode instance this request should be bound to so
        its prefix-cache credit can shorten the prefill, or None for
        hand-off-time placement. A returned pin must be remembered and
        surrendered by ``claim_pin``."""
        return None

    def claim_pin(self, req) -> Optional[int]:
        """Pooled-mode hook, called once at hand-off: pop and return the
        instance id pinned for ``req`` at admission (None if unpinned).
        The router honors the pin while the instance can still serve and
        un-credits the prefix hit when the pin broke mid-prefill."""
        return None


# --------------------------------------------------------------- prefill --
class PrefillPlacement(abc.ABC):
    """Where prefill work runs — the deployment-mode axis
    (docs/cluster.md "Three deployment modes"). One placement object is
    shared by the ``ClusterRouter`` (per-request placement, pump) and
    ``ClusterSim`` (tier scaling, timelines, result fields); standalone
    routers construct one directly from the back-compat kwargs.

    Router-side hooks receive the router; cluster-side hooks receive the
    ``ClusterSim`` (``cs``). Every hook except ``place`` has a no-op
    default, so a minimal placement only decides where a request goes."""

    name: str = ""

    # ---- router side ----
    def on_add_instance(self, inst, now: float, router) -> None:
        """A decode instance joined the fleet."""

    def on_retire_instance(self, inst_id: int, router) -> None:
        """A drained decode instance left the fleet."""

    def saturated(self, cand: List, router) -> bool:
        """Extra admission backpressure beyond decode load (e.g. the
        pooled tier's queue depth). True => reject the request."""
        return False

    @abc.abstractmethod
    def place(self, req, now: float, cand: List, router) -> int:
        """Route an admitted request into this deployment mode. Returns
        the decode instance id, or PENDING when the request entered a
        prefill stage and will reach decode later via ``pump``."""

    def pump(self, until: float, router) -> int:
        """Advance any prefill stage to ``until``, handing completions
        to ``router.dispatch_decode``. Returns requests handed off."""
        return 0

    # ---- cluster side ----
    @classmethod
    def build(cls, cs) -> "PrefillPlacement":
        """Construct for a ``ClusterSim`` (cs exposes cfg_inf, sim,
        cluster, router_cfg)."""
        return cls()

    def spawn_kwargs(self, cs, serves_inference: bool) -> Dict:
        """Extra DecodeInstanceSim kwargs for a (re)spawned instance."""
        return {}

    def on_scale_up(self, cs, t: float) -> None:
        """A decode instance was just added by the autoscaler —
        coordinate the prefill tier (e.g. top the pool up to its floor)."""

    def control(self, cs, t: float, viol_frac: float) -> None:
        """The autoscaler's prefill-loop control slot for this mode:
        evaluate the mode's ScalingPolicy and apply its decision."""

    def retire(self, cs, t: float) -> None:
        """End-of-epoch lifecycle (e.g. retire drained pool workers)."""

    def record_timeline(self, cs, t: float) -> None:
        """Per-epoch timeline point for this tier."""

    def finalize(self, cs, res) -> None:
        """Fill mode-specific ``ClusterResult`` fields."""


# --------------------------------------------------------------- scaling --
class ScalingPolicy(abc.ABC):
    """One autoscaler control loop's decision function. Pure policy: the
    ``Autoscaler`` (core/autoscaler.py) applies cooldowns, records the
    decision stream, and the cluster loop applies actions — a policy
    only maps signals to a ``ScaleDecision``.

    ``signals`` is a plain dict; each loop documents its keys (see
    core/policies/scaling.py for the built-in three)."""

    name: str = ""

    @abc.abstractmethod
    def decide(self, t: float, cfg, signals: Dict):
        """Return a ScaleDecision for control tick ``t`` given
        ``AutoscalerConfig`` ``cfg`` and this loop's signals."""


# ------------------------------------------------------------- migration --
class MigrationPolicy(abc.ABC):
    """Live-KV-migration destination choice (survivability layer,
    core/cluster.py ``KVMigrationConfig``). When an instance receives a
    spot-style preemption warning, ``ClusterSim`` streams each victim
    request's KV to a peer over the interconnect; this policy picks the
    peer. Pure decision: the cluster loop owns the transfer timeline,
    the deadline race and the re-prefill fallback.

    Must be deterministic — migration happens on the seeded failure
    path, and a nondeterministic pick would break the bit-identity
    guarantees the churn tests pin."""

    name: str = ""

    @abc.abstractmethod
    def pick_dest(self, req, cand: List, router):
        """Choose the destination instance for ``req``'s KV from the
        non-empty candidate list (serving peers, victim excluded)."""


# ---------------------------------------------------- adapter placement --
class AdapterPlacement(abc.ABC):
    """Adapter-aware decode placement (multi-LoRA serving,
    core/adapters.py). When ``ClusterConfig.adapters`` is set, the router
    consults this policy *instead of* the routing policy for every
    request carrying an ``adapter_id`` — the trade-off it owns is
    locality (an instance already holding the adapter skips the
    hot-load/swap) versus load balance. Requests without an adapter, and
    all requests when adapter serving is off, still go through the
    ``routing`` policy unchanged.

    Instances expose ``inst.adapters`` (an ``AdapterPool`` or None) for
    residency queries; like routing policies, placements may read router
    and fleet state but must not mutate it, and must be deterministic."""

    name: str = ""

    def __init__(self, cfg):
        self.cfg = cfg               # RouterConfig

    @abc.abstractmethod
    def pick(self, cand: List, req, router):
        """Choose one instance from the non-empty candidate list for the
        adapter-carrying ``req`` (``req.adapter_id >= 0``,
        ``req.adapter_version`` already stamped from the registry)."""


def __getattr__(name: str):
    # lazy re-export: experiment.py imports cluster/router/trace, which
    # import this module — a module-level import here would be a cycle
    if name in ("ExperimentSpec", "SpecError"):
        from repro.core import experiment
        return getattr(experiment, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
