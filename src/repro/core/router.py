"""Cluster-level routing plane: admission -> prefill stage -> decode stage.

Sits above the per-instance QoS machinery (scheduler/allocator/predictor)
and below the cluster event loop. The plane has two tiers, mirroring the
PD-disaggregated deployment the paper assumes (§8.1) and DistServe's
observation that prefill and decode must be scheduled independently:

  1. **Admission** — a request is accepted or rejected against global
     decode saturation (an instance past ``reject_load`` is skipped as long
     as any other can absorb; rejection fires only when none can), plus any
     extra backpressure the prefill placement adds (e.g. the pooled tier's
     queue bound).
  2. **Prefill stage** — owned by the ``PrefillPlacement`` policy object
     (core/api.py): ``chained`` serializes prefill per instance, ``pooled``
     runs the shared ``PrefillPool`` (core/prefill_pool.py), ``chunked``
     has no prefill tier at all (chunks ride decode rounds).
  3. **Decode stage** — the ``RoutingPolicy`` object picks one decode
     instance; the instance admits the request into decode rounds once its
     ``ready_time`` passes.

This module is **pure mechanism**: exactly-once dispatch accounting, the
hand-off path, conservation audit and goodput metrics. Every *decision* —
which instance, where prefill runs, when to scale — lives in a policy
class registered by name through ``repro.core.api`` (built-ins in
``core/policies/``; ``RouterConfig.policy`` and the deployment mode are
registry lookups, so a new policy is a plugin, not a branch here). The
built-in routing policies and their semantics:

  * ``least_loaded``       — join-shortest-queue on the occupancy signal
  * ``round_robin`` / ``random``
  * ``predicted_latency``  — pick the instance with the lowest *predicted
    TPOT* from the fitted TwoStageLatencyPredictor, evaluated at the
    instance's current batch and finetune quantum (falls back to
    least_loaded when no predictor is fitted, e.g. separate mode)
  * ``session_affinity``   — ``Request.session_id`` maps to a sticky
    instance for prefix-cache reuse, overflowing (and remapping) to the
    least-loaded instance when the sticky one is past
    ``affinity_overflow_load``
  * ``cache_aware``        — route to whichever instance's ``PrefixCache``
    holds the longest matching prefix for the session, not just the sticky
    one (core/policies/cache_aware.py — the registry's worked example).
    Pays one synchronous cache peek per candidate per dispatch.
  * ``cache_aware_gossip`` — the fleet-scale variant: scores candidates
    from gossiped, staleness-bounded cache digests (core/gossip.py) with
    zero synchronous peeks on the dispatch path

Session prefix cache (core/prefix_cache.py): when the chosen instance holds
the request's session prefix, ``credit_prefix`` shortens the effective
prefill before any latency is charged. In pooled mode only *pinning*
policies benefit — the decode instance must be known *before* prefill
runs, so such a policy binds the instance at admission
(``RoutingPolicy.pin_for_prefill``) and the pin is honored at hand-off;
other policies choose at hand-off, after prefill already ran at full
length.

Conservation invariant (tested): every request handed to ``dispatch`` is
rejected, still in the prefill stage, or enqueued on exactly one decode
instance — never dropped, never duplicated.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import api
from repro.core.adapters import AdapterRegistry
from repro.core.api import PENDING, REJECTED  # noqa: F401  (legacy home)
from repro.core.costmodel import CostModel
from repro.core.predictor import TwoStageLatencyPredictor
from repro.core.prefill_pool import PrefillPool
from repro.core.simulator import DecodeInstanceSim
from repro.serving.request import Request


@dataclasses.dataclass
class RouterConfig:
    policy: str = "least_loaded"     # any registered routing policy name
    ttft_slo_s: float = 4.0          # prefill SLO (queue + prefill compute)
    tpot_slo_s: float = 0.040        # decode SLO, same target the QoS
    tpot_slack: float = 1.05         # scheduler enforces per round
    tpot_quantile: float = 0.99      # per-request attainment percentile
    reject_load: float = 4.0         # reject when every target's queue
    # exceeds reject_load x max_slots.
    # None = derive from the experiment seed (SimConfig.seed +
    # ROUTER_SEED_SALT in core/cluster.py); any int — including 0 — is an
    # explicit seed and is honored as-is
    seed: Optional[int] = None
    # session_affinity: the sticky instance absorbs its sessions until
    # its load passes this threshold, then the session remaps to the
    # least loaded instance (cache_aware does not use this knob — it
    # trades cache benefit against queue depth continuously)
    affinity_overflow_load: float = 1.0


@dataclasses.dataclass
class RoutedRequest:
    rid: int
    instance: int                    # -1 rejected, -2 in prefill stage
    arrival: float
    adapter_id: int = -1             # tenant adapter (-1 = base model)


@dataclasses.dataclass
class TenantStats:
    """Per-tenant slice of the goodput accounting (multi-LoRA serving):
    DistServe-style attainment evaluated against the tenant's own SLOs
    (Request.ttft_slo_s/tpot_slo_s overrides, else the router-wide
    targets). Keyed by adapter_id in ClusterStats.tenants."""
    offered: int = 0
    completed: int = 0
    attained: int = 0
    ttft_attainment: float = 0.0
    tpot_attainment: float = 0.0
    goodput: float = 0.0
    ttft_p99: float = 0.0
    tpot_p99: float = 0.0
    versions_served: int = 0         # distinct adapter versions completed


@dataclasses.dataclass
class ClusterStats:
    duration: float = 0.0
    offered: int = 0                 # requests presented to the router
    routed: int = 0
    rejected: int = 0
    dropped: int = 0                 # routed but could never fit (oversized)
    completed: int = 0
    attained: int = 0                # completed AND met both SLOs
    throughput: float = 0.0          # completed / duration
    goodput: float = 0.0             # attained / duration  (DistServe)
    slo_attainment: float = 0.0      # attained / offered
    ttft_attainment: float = 0.0
    tpot_attainment: float = 0.0
    ttft_p99: float = 0.0
    tpot_p99: float = 0.0
    # TTFT stage accounting (pool mode): queue wait, prefill compute and
    # decode-admission wait are separately visible, so an SLO miss can be
    # attributed to the tier that caused it
    ttft_queue_p99: float = 0.0      # arrival -> prefill start
    ttft_prefill_p99: float = 0.0    # prefill start -> prefill done
    ttft_decode_wait_p99: float = 0.0  # prefill done -> first decode token
    # degradation-ladder stage 3 (core/cluster.py DegradationConfig):
    # requests hard-rejected after exhausting their shed-backoff retries.
    # Counted inside ``rejected`` too — this field attributes the share
    shed_rejected: int = 0
    # per-tenant attainment (multi-LoRA serving, core/adapters.py);
    # empty unless the trace carries adapter ids
    tenants: Dict[int, TenantStats] = dataclasses.field(default_factory=dict)


def request_slo(r: Request, cfg: RouterConfig):
    """Per-request SLO verdict: (ttft_ok, tpot_ok, ttft, tpot_percentile).
    THE attainment definition — ClusterRouter.stats and every figure that
    plots goodput over time must agree on it, so it lives in one place.
    Only meaningful for completed requests (finish >= 0, tokens emitted).
    Per-tenant SLO overrides on the request take precedence over the
    router-wide targets (the slack multiplier applies either way)."""
    ttft = r.token_times[0] - r.arrival
    samples = r.tpot_samples()
    tpot_p = float(np.percentile(samples, cfg.tpot_quantile * 100)) \
        if samples else 0.0
    ttft_slo = cfg.ttft_slo_s if r.ttft_slo_s is None else r.ttft_slo_s
    tpot_slo = cfg.tpot_slo_s if r.tpot_slo_s is None else r.tpot_slo_s
    ttft_ok = ttft <= ttft_slo
    tpot_ok = tpot_p <= tpot_slo * cfg.tpot_slack
    return ttft_ok, tpot_ok, ttft, tpot_p


class ClusterRouter:
    """Two-stage dispatcher over a mutable fleet of DecodeInstanceSim.

    The fleet is shared with the cluster event loop and the autoscaler:
    instances may be added, put into draining, or have their role flipped
    between control periods; the router re-reads eligibility on every
    dispatch. The routing policy and the prefill placement are policy
    objects resolved through the registry; ``placement=None`` defaults to
    the ``chained`` placement (serialized per-instance prefill).
    """

    def __init__(self, cfg: RouterConfig, prefill_cm: CostModel,
                 predictor: Optional[TwoStageLatencyPredictor] = None,
                 placement: Optional[api.PrefillPlacement] = None,
                 adapter_policy: Optional[api.AdapterPlacement] = None,
                 adapter_registry: Optional[AdapterRegistry] = None):
        self.cfg = cfg
        self.prefill_cm = prefill_cm
        self.predictor = predictor
        self.policy: api.RoutingPolicy = \
            api.resolve_policy("routing", cfg.policy)(cfg)
        if placement is None:
            placement = api.resolve_policy("prefill", "chained")()
        self.placement = placement
        self.mode = placement.name
        # multi-LoRA serving (core/adapters.py): when set, adapter-carrying
        # requests are placed by the adapter_placement policy and stamped
        # with the registry's newest published version at dispatch
        self.adapter_policy = adapter_policy
        self.adapter_registry = adapter_registry
        # fleet-scale cache routing (core/gossip.py): the cluster layer
        # attaches the gossip plane when ``cluster.gossip`` is configured;
        # ``clock`` mirrors the simulation time of the last dispatch so
        # policies can age digests without a ``now`` parameter, and
        # ``dispatch_peeks`` counts synchronous cache probes made on the
        # dispatch path (cache_aware pays O(fleet) of them per request;
        # cache_aware_gossip must stay at zero — tested)
        self.gossip = None
        self.clock = 0.0
        self.dispatch_peeks = 0
        self.instances: Dict[int, DecodeInstanceSim] = {}
        self.retired: Dict[int, DecodeInstanceSim] = {}
        self.routed: List[RoutedRequest] = []
        self._routed_ix: Dict[int, RoutedRequest] = {}
        self._assigned: Dict[int, int] = {}         # rid -> instance id
        # survivability layer (core/cluster.py): rid -> forced decode
        # destination for partially-migrated requests (the KV tail already
        # lives there), and the ladder's hard-rejection counter
        self._forced: Dict[int, int] = {}
        self._shed_rejected = 0

    @property
    def pool(self) -> Optional[PrefillPool]:
        """The pooled placement's PrefillPool (None in other modes) —
        legacy accessor, kept for callers and the conservation audit."""
        return getattr(self.placement, "pool", None)

    # ------------------------------------------------------------ fleet --
    def add_instance(self, inst: DecodeInstanceSim, now: float = 0.0) -> None:
        assert inst.inst_id not in self.instances
        self.instances[inst.inst_id] = inst
        self.placement.on_add_instance(inst, now, self)

    def retire(self, inst_id: int) -> None:
        """Decommission a drained instance: it leaves the active fleet (no
        stepping, no finetune free-running) but stays visible to the final
        accounting — its served requests and finetune progress happened."""
        inst = self.instances.pop(inst_id)
        assert inst.drained, "retiring an instance that still holds work"
        self.placement.on_retire_instance(inst_id, self)
        self.retired[inst_id] = inst

    def kill_instance(self, inst_id: int) -> None:
        """Remove a failed instance from the fleet (cluster failure layer).
        Unlike ``retire`` there is no drained precondition — the caller
        already stripped its in-flight work via ``DecodeInstanceSim.kill``
        and is responsible for requeueing it. The carcass moves to
        ``retired`` so completed-request accounting, broken-pin prefix
        revocation and finetune progress bookkeeping keep working."""
        inst = self.instances.pop(inst_id)
        self.placement.on_retire_instance(inst_id, self)
        self.retired[inst_id] = inst

    def requeue_failed(self, reqs: List[Request], now: float,
                       tails: Optional[Dict[int, tuple]] = None) -> int:
        """Re-admit requests that lost their KV to an instance failure.
        Each request re-enters the normal placement path (re-prefill at
        full length — the cached context is gone) or is rejected when no
        surviving capacity can absorb it. Returns how many re-entered.

        ``tails`` maps rid -> (dest instance id, migrated tokens) for
        requests whose live KV migration lost the deadline race after a
        partial transfer: the request re-prefills only the unsent tail,
        forced onto the destination that already holds the sent prefix.

        The caller must already have detached the requests from the dead
        instance (``DecodeInstanceSim.kill``/``recall``), so deleting the
        stale assignment here keeps exactly-once accounting intact."""
        self.clock = max(self.clock, now)
        n = 0
        for req in sorted(reqs, key=lambda r: (r.arrival, r.rid)):
            rr = self._routed_ix[req.rid]
            del self._assigned[req.rid]
            req.reset_for_retry()
            tail = (tails or {}).get(req.rid)
            if tail is not None:
                dest = self.instances.get(tail[0])
                if dest is not None and dest.serves_inference \
                        and dest.role != "finetune" and not dest.draining:
                    # the partial KV survives on the destination: credit
                    # it and force decode placement there
                    req.migrated_tokens = tail[1]
                    self._forced[req.rid] = tail[0]
            cand = [i for i in self.serving_instances()
                    if i.load() <= self.cfg.reject_load]
            if not cand or self.placement.saturated(cand, self):
                self._forced.pop(req.rid, None)
                req.migrated_tokens = 0
                self._assigned[req.rid] = REJECTED
                rr.instance = REJECTED
                continue
            if self.pool is not None \
                    and self.pool.has_prefill_record(req.rid):
                # erase the lost prefill record so the pool accepts the
                # request again (the produced KV died with the host)
                self.pool.forget(req.rid)
            target = self.placement.place(req, now, cand, self)
            self._assigned[req.rid] = target
            rr.instance = target
            n += 1
        return n

    def migrate(self, req: Request, dest: DecodeInstanceSim, ready: float,
                kind: str) -> None:
        """Land a fully-migrated request on its destination: the KV
        transfer beat the preemption deadline, so at the kill the request
        re-enters the same stage it left — decoding/prefilled requests
        join the ready queue (admissible from ``ready``), mid-chunked-
        prefill ones keep their chunk progress and continue in the
        destination's rounds. The caller already stripped the request
        from the dead victim, so reassignment stays exactly-once."""
        if kind == "chunked":
            dest.enqueue_chunked(req, ready)
        else:
            dest.enqueue(req, ready)
        self._assigned[req.rid] = dest.inst_id
        self._routed_ix[req.rid].instance = dest.inst_id

    def reject_shed(self, req: Request) -> int:
        """Hard-reject a request the degradation ladder shed past its
        retry budget (or that was still backing off at trace end). The
        request was never dispatched — this is its one terminal record."""
        assert req.rid not in self._assigned, "request routed twice"
        self._assigned[req.rid] = REJECTED
        self._record(req, REJECTED)
        self._shed_rejected += 1
        return REJECTED

    def claim_forced(self, req: Request) -> Optional[DecodeInstanceSim]:
        """Pop and return the forced migration destination for ``req``
        (None if unforced). When the destination can no longer take
        traffic the partial-KV credit dies with it — the request falls
        back to full re-prefill wherever the policy sends it."""
        iid = self._forced.pop(req.rid, None)
        if iid is None:
            return None
        dest = self.instances.get(iid)
        if dest is not None and dest.serves_inference \
                and dest.role != "finetune" and not dest.draining:
            return dest
        req.migrated_tokens = 0
        return None

    def has_forced(self, rid: int) -> bool:
        """True while ``rid`` holds an unclaimed forced destination."""
        return rid in self._forced

    def recall_pending(self, rid: int) -> Optional[Request]:
        """Pull a not-yet-admitted request back from its decode instance
        (its pooled prefill worker died before the hand-off's ready time).
        Returns None when the request can't be recalled — e.g. its own
        instance was killed earlier this epoch and it is already back in
        the queue."""
        iid = self._assigned.get(rid, REJECTED)
        inst = self.instances.get(iid) or self.retired.get(iid)
        if inst is None:
            return None
        return inst.recall(rid)

    def all_instances(self) -> List[DecodeInstanceSim]:
        """Active + retired, for end-of-run accounting."""
        return list(self.instances.values()) + list(self.retired.values())

    def serving_instances(self) -> List[DecodeInstanceSim]:
        """Instances eligible for new inference traffic."""
        return [i for i in self.instances.values()
                if i.serves_inference and i.role != "finetune"
                and not i.draining]

    # --------------------------------------------------------- dispatch --
    def credit_prefix(self, inst: DecodeInstanceSim, req: Request) -> None:
        """Consult the chosen instance's prefix cache and shorten the
        request's effective prefill by the cached prefix. Must run before
        any prefill latency is charged. The lookup is bounded by the
        tokens still needing prefill (prompt minus migrated KV) so a
        cache hit is never double-credited on top of a migration credit
        — ``effective_prompt_len`` stays >= 1 by construction."""
        if inst.prefix_cache is not None and req.session_id >= 0:
            avail = req.prompt_len - req.migrated_tokens
            req.cache_hit_tokens = inst.prefix_cache.lookup(
                req.session_id, avail, segments=req.prefix_segments)

    def dispatch(self, req: Request, now: float) -> int:
        """Admit one request and hand it to the prefill placement.
        Returns the decode instance id, PENDING (-2) when the request
        entered a prefill stage, or REJECTED (-1) under global
        saturation. Exactly-once by construction."""
        assert req.rid not in self._assigned, "request routed twice"
        self.clock = max(self.clock, now)
        if self.adapter_registry is not None and req.adapter_id >= 0:
            # continuous deployment: serve whatever version the finetune
            # side has published by now (static baselines only ever see
            # the version published at t=0)
            req.adapter_version = self.adapter_registry.latest(
                req.adapter_id)
        # admission rejects only under GLOBAL saturation: an instance past
        # reject_load is skipped as long as any other can still absorb;
        # the placement may add its own tier's backpressure on top
        cand = [i for i in self.serving_instances()
                if i.load() <= self.cfg.reject_load]
        if not cand or self.placement.saturated(cand, self):
            self._assigned[req.rid] = REJECTED
            self._record(req, REJECTED)
            return REJECTED
        target = self.placement.place(req, now, cand, self)
        self._assigned[req.rid] = target
        self._record(req, target)
        return target

    def _record(self, req: Request, instance: int) -> None:
        rr = RoutedRequest(req.rid, instance, req.arrival, req.adapter_id)
        self.routed.append(rr)
        self._routed_ix[req.rid] = rr

    def pick_decode(self, cand: List[DecodeInstanceSim],
                    req: Request) -> DecodeInstanceSim:
        """Decode-instance choice: the adapter placement policy for
        adapter-carrying requests when multi-LoRA serving is on, else the
        routing policy. Placements call this instead of ``policy.pick``
        so adapter awareness needs no per-mode branches."""
        if self.adapter_policy is not None and req is not None \
                and req.adapter_id >= 0:
            return self.adapter_policy.pick(cand, req, self)
        return self.policy.pick(cand, req, self)

    def pump_prefill(self, until: float) -> int:
        """Advance the prefill stage to ``until`` and hand every completed
        prefill to a decode instance chosen by the routing policy (at
        hand-off time, so the decision sees current fleet state). Returns
        the number of requests handed to the decode stage."""
        self.clock = max(self.clock, until)
        return self.placement.pump(until, self)

    def dispatch_decode(self, req: Request, ready: float) -> int:
        """Decode-stage placement of a prefilled request. Placement always
        succeeds (the request already paid its prefill): saturated
        candidates are preferred in policy order, then any serving
        instance, then any inference-capable one (draining included)."""
        cand = [i for i in self.serving_instances()
                if i.load() <= self.cfg.reject_load]
        if not cand:
            cand = self.serving_instances()
        if not cand:
            cand = [i for i in self.instances.values()
                    if i.serves_inference and i.role != "finetune"]
        assert cand, "no inference-capable instance left in the fleet"
        inst = self.claim_forced(req)
        pin = self.policy.claim_pin(req)
        if inst is not None:
            # partial-migration tail: the sent KV prefix lives on the
            # forced destination, which outranks any admission-time pin
            pin = None
        if pin is not None:
            # instance pinned at admission (its prefix-cache credit already
            # shortened the prefill): honor the pin while the instance can
            # still take traffic; fall back to the policy if it left
            pinned = self.instances.get(pin)
            if pinned is not None and pinned.serves_inference \
                    and pinned.role != "finetune" and not pinned.draining:
                inst = pinned
            elif req.cache_hit_tokens > 0:
                # pin broken mid-prefill (retired / flipped / draining):
                # the shortened prefill already ran and can't be re-costed,
                # but the hit must not count as a cache win — un-credit it
                # on the cache that granted it
                granter = self.instances.get(pin) or self.retired.get(pin)
                if granter is not None and granter.prefix_cache is not None:
                    granter.prefix_cache.revoke(req.cache_hit_tokens)
                req.cache_hit_tokens = 0
        if inst is None:
            inst = self.pick_decode(cand, req)
        inst.enqueue(req, ready)
        self._assigned[req.rid] = inst.inst_id
        self._routed_ix[req.rid].instance = inst.inst_id
        return inst.inst_id

    # ---------------------------------------------------------- metrics --
    def recent_chunk_wait_p99(self, now: float) -> float:
        """Fleet-wide p99 of recent chunked-prefill waits (arrival ->
        prefill-done) — the TTFT-headroom signal the autoscaler's
        chunk-budget loop reads in chunked mode. Per-instance windows are
        merged by pooling the recent samples."""
        samples: List[float] = []
        for inst in self.instances.values():
            samples.extend(inst.recent_chunk_waits(now))
        if not samples:
            return 0.0
        return float(np.percentile(samples, 99))

    def recent_violation_frac(self, window: int = 200) -> float:
        """Fraction of the fleet's last `window` decode-round TPOT samples
        over the SLO — the autoscaler's QoS-headroom signal. Samples are
        merged fleet-wide by time and capped at `window` total (a
        per-instance slice would over-sample big fleets)."""
        samples: List[tuple] = []
        for inst in self.instances.values():
            # per-instance tail is a superset of its share of the fleet tail
            for t, _, lat, bs in inst.quantum_timeline[-window:]:
                if bs > 0:
                    samples.append((t, lat))
        if not samples:
            return 0.0
        samples.sort()
        recent = samples[-window:]
        lim = self.cfg.tpot_slo_s * self.cfg.tpot_slack
        return sum(1 for _, lat in recent if lat > lim) / len(recent)

    def recent_slo_violation_frac(self, window: int = 50) -> float:
        """Fraction of the last `window` COMPLETED requests that missed
        their SLO (TTFT or TPOT, per request_slo) — the degradation
        ladder's overload signal (core/cluster.py). Request-level on
        purpose: the QoS scheduler keeps decode ROUNDS under the TPOT
        budget by construction, so under overload and churn it is TTFT
        queueing that degrades first, and only completed requests carry
        that verdict."""
        done: List[tuple] = []
        for inst in self.all_instances():
            for r in inst.all_reqs:
                if r.finish >= 0 and r.token_times:
                    done.append((r.finish, r.rid, r))
        if not done:
            return 0.0
        done.sort()
        recent = done[-window:]
        bad = 0
        for _, _, r in recent:
            ttft_ok, tpot_ok, _, _ = request_slo(r, self.cfg)
            if not (ttft_ok and tpot_ok):
                bad += 1
        return bad / len(recent)

    def stats(self, duration: float) -> ClusterStats:
        """Cluster goodput accounting over every request the router saw."""
        cfg = self.cfg
        st = ClusterStats(duration=duration, offered=len(self.routed),
                          dropped=sum(i.dropped
                                      for i in self.all_instances()),
                          shed_rejected=self._shed_rejected)
        ttfts: List[float] = []
        tpots: List[float] = []
        stage_q: List[float] = []
        stage_p: List[float] = []
        stage_d: List[float] = []
        reqs: Dict[int, Request] = {}
        for inst in self.all_instances():
            for r in inst.all_reqs:
                reqs[r.rid] = r
        # per-tenant accumulators (adapter-carrying traffic only)
        tn_ttfts: Dict[int, List[float]] = {}
        tn_tpots: Dict[int, List[float]] = {}
        tn_vers: Dict[int, Set[int]] = {}
        for rr in self.routed:
            tn = None
            if rr.adapter_id >= 0:
                tn = st.tenants.setdefault(rr.adapter_id, TenantStats())
                tn.offered += 1
            if rr.instance == REJECTED:
                st.rejected += 1
                continue
            st.routed += 1
            r = reqs.get(rr.rid)
            if r is None or r.finish < 0 or not r.token_times:
                continue
            st.completed += 1
            ttft_ok, tpot_ok, ttft, tpot_p = request_slo(r, cfg)
            ttfts.append(ttft)
            tpots.append(tpot_p)
            if tn is not None:
                tn.completed += 1
                tn.ttft_attainment += ttft_ok
                tn.tpot_attainment += tpot_ok
                tn.attained += ttft_ok and tpot_ok
                tn_ttfts.setdefault(rr.adapter_id, []).append(ttft)
                tn_tpots.setdefault(rr.adapter_id, []).append(tpot_p)
                tn_vers.setdefault(rr.adapter_id, set()).add(
                    r.adapter_version)
            if r.prefill_start >= 0 and r.restarts == 0:
                # went through the pool; restarted requests are excluded —
                # their re-prefill timestamps postdate the first token, so
                # the stage split is meaningless for them
                stage_q.append(r.prefill_start - r.arrival)
                stage_p.append(r.prefill_done - r.prefill_start)
                stage_d.append(r.token_times[0] - r.prefill_done)
            st.ttft_attainment += ttft_ok
            st.tpot_attainment += tpot_ok
            if ttft_ok and tpot_ok:
                st.attained += 1
        if duration > 0:
            st.throughput = st.completed / duration
            st.goodput = st.attained / duration
        if st.offered:
            st.slo_attainment = st.attained / st.offered
        if st.completed:
            st.ttft_attainment /= st.completed
            st.tpot_attainment /= st.completed
        if ttfts:
            st.ttft_p99 = float(np.percentile(ttfts, 99))
        if tpots:
            st.tpot_p99 = float(np.percentile(tpots, 99))
        if stage_q:
            st.ttft_queue_p99 = float(np.percentile(stage_q, 99))
            st.ttft_prefill_p99 = float(np.percentile(stage_p, 99))
            st.ttft_decode_wait_p99 = float(np.percentile(stage_d, 99))
        for aid, tn in st.tenants.items():
            if tn.completed:
                tn.ttft_attainment /= tn.completed
                tn.tpot_attainment /= tn.completed
            if duration > 0:
                tn.goodput = tn.attained / duration
            if tn_ttfts.get(aid):
                tn.ttft_p99 = float(np.percentile(tn_ttfts[aid], 99))
                tn.tpot_p99 = float(np.percentile(tn_tpots[aid], 99))
            tn.versions_served = len(tn_vers.get(aid, ()))
        return st

    def check_conservation(self) -> None:
        """Every offered request rejected, still in the prefill stage, or
        enqueued on exactly one decode instance; every enqueued request
        traces back to exactly one dispatch."""
        seen = [rr.rid for rr in self.routed]
        assert len(seen) == len(set(seen)), "request dispatched twice"
        enq: Dict[int, int] = {}
        for inst in self.all_instances():
            for r in inst.all_reqs:
                assert r.rid not in enq, "request on two instances"
                enq[r.rid] = inst.inst_id
        pending = 0
        for rr in self.routed:
            if rr.instance == REJECTED:
                assert rr.rid not in enq, "rejected request was enqueued"
            elif rr.instance == PENDING:
                assert rr.rid not in enq, "pending request was enqueued"
                pending += 1
            else:
                assert enq.get(rr.rid) == rr.instance, "assignment mismatch"
        assert len(enq) == sum(1 for rr in self.routed if rr.instance >= 0)
        if self.pool is not None:
            assert pending == self.pool.queue_depth, \
                "prefill-stage count disagrees with the pool queue"
            self.pool.check_conservation()
        else:
            assert pending == 0
