"""Cluster-level request router: global admission + load-aware dispatch.

Sits above the per-instance QoS machinery (scheduler/allocator/predictor):
the router decides *which* decode instance serves a request — or rejects it
when the whole fleet is saturated — while each instance keeps deciding *how*
to share its chips between decode rounds and finetune quanta.

Design follows DistServe (Zhong et al., OSDI'24): the cluster objective is
**goodput** — completed requests per second that attain BOTH latency SLOs
(TTFT for the prefill phase, TPOT for decode) — not raw throughput. The
router therefore tracks per-request SLO attainment and exposes cluster
goodput accounting; the autoscaler (core/autoscaler.py) consumes the same
signals to resize the fleet.

Conservation invariant (tested): every request handed to ``dispatch`` is
either enqueued on exactly one instance or rejected — never both, never
dropped, never duplicated.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.costmodel import CostModel
from repro.core.simulator import DecodeInstanceSim
from repro.serving.request import Request

POLICIES = ("least_loaded", "round_robin", "random")


@dataclasses.dataclass
class RouterConfig:
    policy: str = "least_loaded"
    ttft_slo_s: float = 4.0          # prefill SLO (queue + prefill compute)
    tpot_slo_s: float = 0.040        # decode SLO, same target the QoS
    tpot_slack: float = 1.05         # scheduler enforces per round
    tpot_quantile: float = 0.99      # per-request attainment percentile
    reject_load: float = 4.0         # reject when the best target's queue
    seed: int = 0                    # exceeds reject_load x max_slots


@dataclasses.dataclass
class RoutedRequest:
    rid: int
    instance: int                    # -1 = rejected at admission
    arrival: float


@dataclasses.dataclass
class ClusterStats:
    duration: float = 0.0
    offered: int = 0                 # requests presented to the router
    routed: int = 0
    rejected: int = 0
    dropped: int = 0                 # routed but could never fit (oversized)
    completed: int = 0
    attained: int = 0                # completed AND met both SLOs
    throughput: float = 0.0          # completed / duration
    goodput: float = 0.0             # attained / duration  (DistServe)
    slo_attainment: float = 0.0      # attained / offered
    ttft_attainment: float = 0.0
    tpot_attainment: float = 0.0
    ttft_p99: float = 0.0
    tpot_p99: float = 0.0


class ClusterRouter:
    """Load-aware dispatcher over a mutable fleet of DecodeInstanceSim.

    The fleet is shared with the cluster event loop and the autoscaler:
    instances may be added, put into draining, or have their role flipped
    between control periods; the router re-reads eligibility on every
    dispatch. One prefill chain is modeled per serving instance (the paper
    deploys PD-disaggregated, prefill pool scaling with decode capacity).
    """

    def __init__(self, cfg: RouterConfig, prefill_cm: CostModel):
        assert cfg.policy in POLICIES, cfg.policy
        self.cfg = cfg
        self.prefill_cm = prefill_cm
        self.instances: Dict[int, DecodeInstanceSim] = {}
        self.retired: Dict[int, DecodeInstanceSim] = {}
        self._prefill_free: Dict[int, float] = {}   # per-instance chain time
        self.routed: List[RoutedRequest] = []
        self._assigned: Dict[int, int] = {}         # rid -> instance id
        self._rng = np.random.default_rng(cfg.seed)
        self._rr_cursor = 0

    # ------------------------------------------------------------ fleet --
    def add_instance(self, inst: DecodeInstanceSim, now: float = 0.0) -> None:
        assert inst.inst_id not in self.instances
        self.instances[inst.inst_id] = inst
        self._prefill_free[inst.inst_id] = now

    def retire(self, inst_id: int) -> None:
        """Decommission a drained instance: it leaves the active fleet (no
        stepping, no finetune free-running) but stays visible to the final
        accounting — its served requests and finetune progress happened."""
        inst = self.instances.pop(inst_id)
        assert inst.drained, "retiring an instance that still holds work"
        self._prefill_free.pop(inst_id, None)
        self.retired[inst_id] = inst

    def all_instances(self) -> List[DecodeInstanceSim]:
        """Active + retired, for end-of-run accounting."""
        return list(self.instances.values()) + list(self.retired.values())

    def serving_instances(self) -> List[DecodeInstanceSim]:
        """Instances eligible for new inference traffic."""
        return [i for i in self.instances.values()
                if i.serves_inference and i.role != "finetune"
                and not i.draining]

    # --------------------------------------------------------- dispatch --
    def _pick_target(self, cand: List[DecodeInstanceSim]
                     ) -> DecodeInstanceSim:
        if self.cfg.policy == "round_robin":
            pick = cand[self._rr_cursor % len(cand)]
            self._rr_cursor += 1
            return pick
        if self.cfg.policy == "random":
            return cand[int(self._rng.integers(len(cand)))]
        # least_loaded (join-shortest-queue on the occupancy signal);
        # ties broken by instance id for determinism
        return min(cand, key=lambda i: (i.load(), i.inst_id))

    def dispatch(self, req: Request, now: float) -> int:
        """Route one request. Returns the chosen instance id, or -1 when
        admission rejects it (fleet saturated). Exactly-once by
        construction: a request is enqueued on one instance or none."""
        assert req.rid not in self._assigned, "request routed twice"
        # admission rejects only under GLOBAL saturation: an instance past
        # reject_load is skipped as long as any other can still absorb
        cand = [i for i in self.serving_instances()
                if i.load() <= self.cfg.reject_load]
        if not cand:
            self._assigned[req.rid] = -1
            self.routed.append(RoutedRequest(req.rid, -1, req.arrival))
            return -1
        inst = self._pick_target(cand)
        # prefill chain: request queues behind earlier prefills on the
        # instance's prefill partner, then decode admission takes over
        t_start = max(self._prefill_free[inst.inst_id], req.arrival, now)
        ready = t_start + self.prefill_cm.prefill_latency(req.prompt_len)
        self._prefill_free[inst.inst_id] = ready
        req.prefill_done = ready
        inst.enqueue(req, ready)
        self._assigned[req.rid] = inst.inst_id
        self.routed.append(RoutedRequest(req.rid, inst.inst_id, req.arrival))
        return inst.inst_id

    # ---------------------------------------------------------- metrics --
    def recent_violation_frac(self, window: int = 200) -> float:
        """Fraction of the fleet's last `window` decode-round TPOT samples
        over the SLO — the autoscaler's QoS-headroom signal."""
        samples: List[float] = []
        for inst in self.instances.values():
            for _, _, lat, bs in inst.quantum_timeline[-window:]:
                if bs > 0:
                    samples.append(lat)
        if not samples:
            return 0.0
        lim = self.cfg.tpot_slo_s * self.cfg.tpot_slack
        return sum(1 for s in samples if s > lim) / len(samples)

    def stats(self, duration: float) -> ClusterStats:
        """Cluster goodput accounting over every request the router saw."""
        cfg = self.cfg
        st = ClusterStats(duration=duration, offered=len(self.routed),
                          dropped=sum(i.dropped
                                      for i in self.all_instances()))
        ttfts: List[float] = []
        tpots: List[float] = []
        reqs: Dict[int, Request] = {}
        for inst in self.all_instances():
            for r in inst.all_reqs:
                reqs[r.rid] = r
        for rr in self.routed:
            if rr.instance < 0:
                st.rejected += 1
                continue
            st.routed += 1
            r = reqs.get(rr.rid)
            if r is None or r.finish < 0 or not r.token_times:
                continue
            st.completed += 1
            ttft = r.token_times[0] - r.arrival
            samples = r.tpot_samples()
            tpot_p = float(np.percentile(samples, cfg.tpot_quantile * 100)) \
                if samples else 0.0
            ttfts.append(ttft)
            tpots.append(tpot_p)
            ttft_ok = ttft <= cfg.ttft_slo_s
            tpot_ok = tpot_p <= cfg.tpot_slo_s * cfg.tpot_slack
            st.ttft_attainment += ttft_ok
            st.tpot_attainment += tpot_ok
            if ttft_ok and tpot_ok:
                st.attained += 1
        if duration > 0:
            st.throughput = st.completed / duration
            st.goodput = st.attained / duration
        if st.offered:
            st.slo_attainment = st.attained / st.offered
        if st.completed:
            st.ttft_attainment /= st.completed
            st.tpot_attainment /= st.completed
        if ttfts:
            st.ttft_p99 = float(np.percentile(ttfts, 99))
        if tpots:
            st.tpot_p99 = float(np.percentile(tpots, 99))
        return st

    def check_conservation(self) -> None:
        """Every offered request routed exactly once or rejected; every
        enqueued request traces back to exactly one dispatch."""
        seen = [rr.rid for rr in self.routed]
        assert len(seen) == len(set(seen)), "request dispatched twice"
        enq: Dict[int, int] = {}
        for inst in self.all_instances():
            for r in inst.all_reqs:
                assert r.rid not in enq, "request on two instances"
                enq[r.rid] = inst.inst_id
        for rr in self.routed:
            if rr.instance < 0:
                assert rr.rid not in enq, "rejected request was enqueued"
            else:
                assert enq.get(rr.rid) == rr.instance, "assignment mismatch"
        assert len(enq) == sum(1 for rr in self.routed if rr.instance >= 0)
