"""Discrete-event co-location simulator (paper-scale experiments, Figs 11-14).

Replays a request trace against the roofline cost model on a modeled TPU v5e
deployment: one prefill instance + N decode instances (TP groups), each
optionally co-locating a PEFT finetune job through the unified allocator,
two-stage predictor and QoS scheduler — the same classes the real engine
uses; only step execution is virtual (costmodel latencies instead of XLA).

Modes (paper §8.1):
  separate — decode on instance 0, finetune solo on instance 1
  static   — both instances co-located at a fixed 60/40 split
  harli    — both instances co-located, dynamic quantum + window
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.adapters import AdapterPool, InstanceAdapterConfig
from repro.core.allocator import AllocatorConfig, UnifiedAllocator
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.predictor import TwoStageLatencyPredictor
from repro.core.prefix_cache import PrefixCache, PrefixCacheConfig
from repro.core.scheduler import QoSScheduler, SchedulerConfig
from repro.distributed.fault_tolerance import (CheckpointManager,
                                               StragglerConfig,
                                               StragglerMitigator)
from repro.models.config import ModelConfig
from repro.serving.request import Request


@dataclasses.dataclass
class SimConfig:
    mode: str = "harli"                 # harli | static | separate
    qos_s: float = 0.040
    k_max: int = 10
    micro_batch: int = 2
    ft_seq: int = 1024
    accum: int = 8
    max_slots: int = 64
    n_decode_instances: int = 2
    tp: int = 2            # 2 x 16GB chips: tight like the paper's Ada6000
    static_quantum: float = 0.4         # StaticMode: 40% to finetune
    static_mem_frac: float = 0.4        # StaticMode: 40% memory to finetune
    share_base_weights: bool = False    # beyond-paper same-model sharing
    snapshot_every: int = 20            # allocator timeline granularity
    straggler_prob: float = 0.0         # per-round chance of a 3-8x overrun
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    mode: str
    ft_units_done: int = 0
    ft_iterations: float = 0.0
    ft_throughput: float = 0.0          # iterations/s x minibatch (paper §8.2)
    ft_stall_rounds: int = 0
    tpot: List[float] = dataclasses.field(default_factory=list)
    qos_violation_frac: float = 0.0
    completed: int = 0
    duration: float = 0.0
    decode_rounds: int = 0
    mean_batch: float = 0.0
    batch_timeline: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)
    quantum_timeline: List[Tuple[float, int, float, int]] = \
        dataclasses.field(default_factory=list)   # (t, k, round_latency, bs)
    memory_timeline: List[Dict] = dataclasses.field(default_factory=list)
    predictor_report: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class ChunkedPrefillConfig:
    """prefill_mode="chunked" (core/cluster.py): prefill chunks are mixed
    into decode rounds on the serving instance itself, under a per-round
    token budget — no separate prefill tier. The budget is the control
    knob the autoscaler's mode-aware prefill loop tunes against TTFT
    headroom (``Autoscaler.evaluate_chunked``)."""
    budget_tokens: int = 256         # per-round chunk budget at t=0
    min_budget: int = 64             # autoscaler tuning range
    max_budget: int = 1024
    chunk_wait_window_s: float = 15.0   # recency horizon, TTFT signal
    # fraction of the TPOT target a chunk-carrying round may fill: the
    # remainder absorbs predictor fit error and measurement noise, so
    # admitting chunks at the priced limit doesn't push per-request TPOT
    # p99 over the SLO (which carries only tpot_slack=5% of slack)
    qos_margin: float = 0.85
    # fuse a REDUCED finetune quantum into chunk-carrying rounds when the
    # predictor says quantum + chunk together still fit the round budget
    # (``predict_mixed(k/k_max, ...) <= qos_margin * TPOT-SLO``), instead
    # of forcing quantum 0 on every chunk round. Default off: the original
    # inference-preempts-finetune behaviour (§2.3) is the pinned baseline
    fuse_quantum: bool = False


# ---------------------------------------------------------------- finetune
class FinetuneSim:
    """Layer-unit progress + window streaming state machine."""

    def __init__(self, cfg_ft: ModelConfig, cm: CostModel, sim: SimConfig,
                 allocator: UnifiedAllocator, weights_resident: bool,
                 fixed_window_chunks: Optional[int] = None):
        self.cfg = cfg_ft
        self.cm = cm
        self.sim = sim
        self.alloc = allocator
        self.weights_resident = weights_resident
        self.fixed_window_chunks = fixed_window_chunks
        L = cfg_ft.num_layers
        # unit -> layer id (-1 = no weights needed: embed/head/opt units)
        per_mb = [-1] + list(range(L)) + [-1] + list(range(L - 1, -1, -1)) \
            + [-1]
        self.unit_layers = per_mb * sim.accum + [-1]
        self.units_per_iter = len(self.unit_layers)
        self.layer_bytes = cfg_ft.active_param_count() / max(L, 1) * 2.0
        self.swap_s = self.layer_bytes / cm.inst.host_dma_bw
        self.layers_per_chunk = max(
            int(allocator.chunk_bytes // self.layer_bytes), 1) \
            if self.layer_bytes > allocator.chunk_bytes else None
        self.chunks_per_layer = max(
            math.ceil(self.layer_bytes / allocator.chunk_bytes), 1)
        # window state
        self.resident: List[int] = []
        self.dma_busy_until = 0.0
        self.dma_loading: Optional[int] = None
        self._need_known_t = 0.0
        self.cursor = 0                  # next unit index (mod units_per_iter)
        self.units_done = 0
        self.stall_rounds = 0

    # -- window geometry ---------------------------------------------------
    def window_layers_cap(self) -> int:
        if self.weights_resident:
            return self.cfg.num_layers
        chunks = self.alloc.window_capacity_chunks()
        if self.fixed_window_chunks is not None:      # StaticMode
            chunks = min(chunks, self.fixed_window_chunks)
        return min(max(chunks // self.chunks_per_layer, 0),
                   self.cfg.num_layers)

    def _need_order(self, start_unit: int, horizon: int = 64) -> List[int]:
        """Upcoming distinct layers in unit order."""
        out, seen = [], set()
        for d in range(horizon):
            lay = self.unit_layers[(start_unit + d) % self.units_per_iter]
            if lay >= 0 and lay not in seen:
                seen.add(lay)
                out.append(lay)
        return out

    def pump_dma(self, t: float) -> None:
        """Advance the streaming channel's timeline up to time t. Loads
        chain back-to-back on the channel; a load started at s lands at
        s + swap_s. Needs become known at advance() time (= pump calls)."""
        if self.weights_resident:
            return
        cap = self.window_layers_cap()
        # inference memory pressure: evict furthest-from-need beyond cap
        while len(self.resident) > cap:
            order = self._need_order(self.cursor)
            furthest = max(self.resident,
                           key=lambda l: order.index(l) if l in order
                           else len(order) + l)
            self.resident.remove(furthest)
        self.alloc.resize_window(
            len(self.resident) * self.chunks_per_layer)
        while True:
            if self.dma_loading is not None:
                if self.dma_busy_until > t:
                    return                       # still streaming
                if len(self.resident) < cap:
                    self.resident.append(self.dma_loading)
                    self.alloc.resize_window(
                        len(self.resident) * self.chunks_per_layer)
                self.dma_loading = None
            order = self._need_order(self.cursor,
                                     horizon=2 * self.units_per_iter
                                     if self.units_per_iter < 2048 else 256)
            nxt = next((l for l in order if l not in self.resident), None)
            if nxt is None or cap == 0:
                return
            if len(self.resident) >= cap:
                # paper §4.3: evict the completed layer to prefetch the next
                # (Belady: furthest-from-next-use victim)
                def dist(l):
                    return order.index(l) if l in order else 10 ** 9
                victim = max(self.resident, key=dist)
                if dist(victim) <= order.index(nxt):
                    return               # every resident layer needed sooner
                self.resident.remove(victim)
            # chain from the previous completion; a fresh need starts now
            start = max(self.dma_busy_until, self._need_known_t)
            self.dma_loading = nxt
            self.dma_busy_until = start + self.swap_s

    def units_available(self, t: float, k_max: int) -> int:
        """How many consecutive upcoming units can run right now."""
        self.pump_dma(t)
        n = 0
        for d in range(k_max):
            lay = self.unit_layers[(self.cursor + d) % self.units_per_iter]
            if lay >= 0 and not self.weights_resident and \
                    lay not in self.resident:
                break
            n += 1
        return n

    def advance(self, k: int, t_end: float) -> None:
        self.cursor = (self.cursor + k) % self.units_per_iter
        self.units_done += k
        self._need_known_t = t_end
        self.pump_dma(t_end)

    @property
    def iterations(self) -> float:
        return self.units_done / self.units_per_iter

    def avg_unit_time_solo(self) -> float:
        f = self.cm.unit_solo(self.sim.micro_batch, self.sim.ft_seq,
                              backward=False, noisy=False)
        b = self.cm.unit_solo(self.sim.micro_batch, self.sim.ft_seq,
                              backward=True, noisy=False)
        return (f + b) / 2


class FinetuneCheckpointer:
    """Periodic durable commit of a finetune job's progress through the
    fault-tolerance ``CheckpointManager`` (distributed/fault_tolerance.py).

    The cluster failure layer attaches one per finetune-carrying instance
    when failure injection is on: every ``interval_s`` of sim time the
    job's progress commits as a real on-disk checkpoint (restore after a
    kill reads it back — the module's atomic-manifest path is exercised,
    not mocked), and the commit's device->host stream cost
    (``CostModel.checkpoint_time``) is charged to the finetune quantum
    budget — rounds inside the commit window run quantum 0."""

    def __init__(self, directory, interval_s: float, commit_time_s: float,
                 t0: float = 0.0, keep: int = 2):
        self.mgr = CheckpointManager(directory, keep=keep)
        self.interval_s = interval_s
        self.commit_time_s = commit_time_s
        self.last_commit_t = t0
        self.busy_until = -1.0
        self.commits = 0

    def busy(self, t: float) -> bool:
        """True while a commit's device->host stream is still running —
        the finetune job yields its quantum for these rounds."""
        return t < self.busy_until

    def maybe_commit(self, t: float, units_done: int) -> bool:
        """Commit when the cadence is due. Returns True iff a commit was
        started at ``t`` (the caller charges the round's quantum to it)."""
        if t - self.last_commit_t < self.interval_s:
            return False
        self.commit(t, units_done)
        return True

    def commit(self, t: float, units_done: int) -> None:
        self.commits += 1
        self.mgr.save(self.commits, {"units_done": np.asarray(units_done)})
        self.last_commit_t = t
        self.busy_until = t + self.commit_time_s

    def restore_units(self) -> int:
        """Progress at the last durable commit (0 before the first one)."""
        if self.mgr.latest_step() is None:
            return 0
        return int(self.mgr.restore({"units_done": None})["units_done"])


# ----------------------------------------------------------- decode + colo
# Instance roles (autoscaler-controlled; see core/autoscaler.py):
#   decode    — inference only, finetune quantum forced to 0
#   colocated — inference + co-scheduled finetune (harli/static behaviour)
#   finetune  — dedicated finetune instance; free-runs whenever idle
ROLES = ("decode", "colocated", "finetune")


class DecodeInstanceSim:
    """One decode instance, drivable by an external event loop.

    Two usage modes:
      * single-instance experiments call ``run(reqs, ready_times, duration)``
        (the original monolithic loop, now a thin wrapper);
      * the cluster layer (core/cluster.py) calls ``enqueue`` as the router
        dispatches requests and ``step(until)`` to advance one event at a
        time, interleaving instances on a shared clock.
    """

    def __init__(self, inst_id: int, cfg_inf: ModelConfig,
                 cfg_ft: Optional[ModelConfig], sim: SimConfig,
                 predictor: Optional[TwoStageLatencyPredictor], seed: int,
                 serves_inference: bool = True, t0: float = 0.0,
                 role: Optional[str] = None, *,
                 chunked: Optional[ChunkedPrefillConfig] = None,
                 prefix_cache: Optional[PrefixCacheConfig] = None,
                 ckpt: Optional[FinetuneCheckpointer] = None,
                 adapters: Optional[InstanceAdapterConfig] = None):
        self.inst_id = inst_id
        self.sim = sim
        self.cfg_inf = cfg_inf
        self.serves_inference = serves_inference
        self.predictor = predictor
        spec = InstanceSpec(tp=sim.tp)
        self.cm_inf = CostModel(cfg_inf, spec, seed=seed)
        self.colocate = cfg_ft is not None

        weights = cfg_inf.param_count() * 2.0 if serves_inference else 0.0
        pool = int(spec.hbm_bytes - weights)
        assert pool > 0, "inference weights exceed instance HBM"
        swap_guess = 0.002
        self.alloc = UnifiedAllocator(AllocatorConfig(
            total_bytes=pool, n_layers=cfg_inf.num_layers,
            kv_bytes_per_token=cfg_inf.cache_bytes_per_token()
            + (cfg_inf.state_bytes() // max(sim.max_slots, 1) if
               cfg_inf.state_bytes() else 0),
            max_bs=sim.max_slots, qos_s=sim.qos_s, swap_time_s=swap_guess))
        fixed_window = None
        if sim.mode == "static" and self.colocate:
            # static 60/40 split: finetune owns a fixed fraction of the pool
            fixed_window = int(self.alloc.total_chunks * sim.static_mem_frac)
        self.ft: Optional[FinetuneSim] = None
        if self.colocate:
            cm_ft = CostModel(cfg_ft, spec, seed=seed + 1)
            resident = sim.share_base_weights and cfg_ft.name == cfg_inf.name
            self.ft = FinetuneSim(cfg_ft, cm_ft, sim, self.alloc, resident,
                                  fixed_window_chunks=fixed_window)
            self.alloc.cfg.swap_time_s = self.ft.swap_s
        self.sched = None
        if predictor is not None and sim.mode == "harli" and self.colocate:
            self.sched = QoSScheduler(predictor, SchedulerConfig(
                qos_s=sim.qos_s, k_max=sim.k_max))
        # decode-round deadline monitor: overruns (preempted host, slow
        # chip) shed finetune work first — never inference
        self.straggler = StragglerMitigator(StragglerConfig())
        self._rng = np.random.default_rng(seed + 101)
        # inference admission budget (chunks): StaticMode caps inference at
        # its static share; otherwise everything minus the reserve is usable
        if sim.mode == "static" and self.colocate:
            self.kv_budget_chunks = int(
                self.alloc.total_chunks * (1 - sim.static_mem_frac))
        else:
            self.kv_budget_chunks = (self.alloc.total_chunks
                                     - self.alloc.reserved_chunks)
        self.result_tpot: List[float] = []
        self.batch_timeline: List[Tuple[float, int]] = []
        self.quantum_timeline: List[Tuple[float, int, float, int]] = []
        self.rounds = 0
        self.bs_accum = 0
        # ---- external-event-loop state ---------------------------------
        if role is None:
            role = "colocated" if self.colocate else "decode"
            if not serves_inference:
                role = "finetune"
        assert role in ROLES, role
        self.role = role
        self.t = t0                      # instance-local clock
        self.draining = False            # router stops dispatching here
        # ---- failure layer (core/cluster.py, ClusterConfig.failures) ----
        self.ckpt = ckpt if self.colocate else None
        self.preempt_deadline = -1.0     # >= 0: spot-style notice received
        # degradation-ladder stage 1 (core/cluster.py DegradationConfig):
        # fleet-wide finetune circuit breaker — colocated quantum forced
        # to 0 until the violation fraction recovers
        self.ft_breaker = False
        self.killed_at = -1.0            # >= 0: hard-killed at this time
        self.active: List[Request] = []
        self._pending: List[Tuple[float, int, Request]] = []   # ready heap
        self.all_reqs: List[Request] = []
        self.dropped = 0                 # requests that could never fit
        self._snap_ctr = 0
        # ---- chunked prefill (prefill_mode="chunked") -------------------
        self.chunked = chunked
        self.chunk_budget = chunked.budget_tokens if chunked else 0
        # FIFO over arrival: chunked prefill keeps arrival order (the EDF
        # reordering lives in the pooled tier; here fairness is per-round)
        self._chunk_pending: List[Tuple[float, int, Request]] = []
        self.chunk_timeline: List[Tuple[float, int, int]] = []  # (t,tok,bud)
        self.chunk_waits: Deque[Tuple[float, float]] = deque()  # (done,wait)
        # ---- session prefix cache ---------------------------------------
        # reserved AFTER kv_budget_chunks: the cache's chunks come out of
        # the KV admission budget, so cached prefixes are paid-for memory
        self.prefix_cache: Optional[PrefixCache] = None
        if prefix_cache is not None and serves_inference:
            self.prefix_cache = PrefixCache(prefix_cache, self.alloc)
            self.kv_budget_chunks = max(
                self.kv_budget_chunks - self.prefix_cache.granted_chunks, 1)
        # ---- multi-LoRA adapter serving (core/adapters.py) --------------
        # resident adapter chunks are charged dynamically: _can_admit and
        # kv_headroom_chunks subtract alloc.adapter_chunks, so hot-loads
        # genuinely compete with KV admission instead of pre-carving a
        # static budget slice
        self.adapters: Optional[AdapterPool] = None
        if adapters is not None and serves_inference:
            self.adapters = AdapterPool(self.alloc, adapters)

    # -- external event-loop API ------------------------------------------
    def set_role(self, role: str) -> None:
        assert role in ROLES, role
        if role == "colocated":
            assert self.colocate, "instance has no finetune job to resume"
        self.role = role

    def enqueue(self, req: Request, ready_time: float) -> None:
        """Hand a request to this instance; it becomes admissible once its
        prefill completes at ``ready_time``."""
        heapq.heappush(self._pending, (ready_time, req.rid, req))
        self.all_reqs.append(req)
        if self.adapters is not None:
            self.adapters.require(req.adapter_id, req.adapter_version)

    def enqueue_chunked(self, req: Request, now: float) -> None:
        """Hand a request whose prefill this instance will run in chunks
        mixed into its own decode rounds (prefill_mode="chunked"). The
        request joins the decode queue once its last chunk completes."""
        assert self.chunked is not None, "instance not in chunked mode"
        heapq.heappush(self._chunk_pending,
                       (max(req.arrival, now), req.rid, req))
        self.all_reqs.append(req)
        if self.adapters is not None:
            self.adapters.require(req.adapter_id, req.adapter_version)

    def recall(self, rid: int) -> Optional[Request]:
        """Pull a not-yet-admitted request back out of the ready queue (its
        pooled prefill worker died, so the KV it was waiting on is gone).
        Only pending requests can be recalled — an admitted one holds real
        KV on *this* instance and is unaffected by a worker's death."""
        for i, (_, r_rid, req) in enumerate(self._pending):
            if r_rid == rid:
                self._pending.pop(i)
                heapq.heapify(self._pending)
                self.all_reqs = [r for r in self.all_reqs if r.rid != rid]
                return req
        return None

    def migratable(self) -> List[Tuple[Request, str, float]]:
        """In-flight requests a live KV migration could move off this
        instance, as ``(request, kind, ready_time)`` — kind tells the
        router which queue the request re-enters on the destination:
        ``active`` (decoding, full context resident), ``pending`` (prefill
        done, KV waiting for admission) or ``chunked`` (mid chunked
        prefill). Deterministic order: active by rid, then the queues in
        heap-key order."""
        out: List[Tuple[Request, str, float]] = []
        for r in sorted(self.active, key=lambda r: r.rid):
            out.append((r, "active", self.t))
        for ready, _, req in sorted(self._pending):
            out.append((req, "pending", ready))
        for arr, _, req in sorted(self._chunk_pending):
            out.append((req, "chunked", arr))
        return out

    def kv_headroom_chunks(self) -> int:
        """Free KV admission budget under the conservative reservation
        ``_can_admit`` uses (prompt + max output for every in-flight
        request) — the signal the default migration destination policy
        ranks candidates by."""
        tok = sum(r.prompt_len + r.max_new_tokens for r in self.active)
        tok += sum(req.prompt_len + req.max_new_tokens
                   for _, _, req in self._pending)
        tok += sum(req.prompt_len + req.max_new_tokens
                   for _, _, req in self._chunk_pending)
        return self.kv_budget_chunks - self.alloc.adapter_chunks \
            - math.ceil(tok / self.alloc.tokens_per_chunk)

    def begin_preempt(self, deadline: float) -> None:
        """Spot-style preemption notice: drain gracefully until
        ``deadline``. No new dispatches land here (draining), the finetune
        job commits a final checkpoint and stops — whatever decode work
        remains at the deadline dies with the host."""
        self.draining = True
        self.preempt_deadline = deadline
        if self.ckpt is not None and self.ft is not None:
            self.ckpt.commit(self.t, self.ft.units_done)

    def kill(self, t: float) -> Tuple[List[Request], float]:
        """Hard instance failure at ``t``: every in-flight request loses
        its KV cache (the caller requeues them through the router), the
        prefix cache is invalidated, and the finetune job rolls back to
        its last durable checkpoint. Returns ``(lost_requests,
        ft_iterations_lost)``; completed requests stay in ``all_reqs`` —
        they happened."""
        lost = list(self.active)
        lost += [item[2] for item in self._pending]
        lost += [item[2] for item in self._chunk_pending]
        self.active = []
        self._pending = []
        self._chunk_pending = []
        lost_rids = {r.rid for r in lost}
        self.all_reqs = [r for r in self.all_reqs
                         if r.rid not in lost_rids]
        self.draining = True
        self.killed_at = t
        ft_lost_iters = 0.0
        if self.ft is not None:
            restored = 0
            if self.ckpt is not None:
                restored = min(self.ckpt.restore_units(),
                               self.ft.units_done)
            ft_lost_iters = (self.ft.units_done - restored) \
                / self.ft.units_per_iter
            self.ft.units_done = restored
            self.ft.cursor = restored % self.ft.units_per_iter
        if self.prefix_cache is not None:
            self.prefix_cache.invalidate_all()
        if self.adapters is not None:
            self.adapters.evict_all()
        return lost, ft_lost_iters

    @property
    def queue_depth(self) -> int:
        return len(self._pending) + len(self._chunk_pending) \
            + len(self.active)

    @property
    def drained(self) -> bool:
        """True once a draining instance has emptied and may be retired."""
        return self.draining and not self.active and not self._pending \
            and not self._chunk_pending

    def load(self) -> float:
        """Occupancy signal for the router/autoscaler: active + queued
        requests relative to the slot budget (may exceed 1.0)."""
        return self.queue_depth / max(self.sim.max_slots, 1)

    def _can_admit(self, active: List[Request], cand: Request) -> bool:
        """vLLM-style conservative admission: reserve prompt + max output
        for every active request so decode never runs out of KV memory."""
        tok = cand.prompt_len + cand.max_new_tokens
        tok += sum(r.prompt_len + r.max_new_tokens for r in active)
        need = math.ceil(tok / self.alloc.tokens_per_chunk)
        # resident LoRA adapters occupy real chunks: admission competes
        # with them (adapter_chunks is 0 when adapter serving is off)
        return need <= self.kv_budget_chunks - self.alloc.adapter_chunks

    def _pick_k(self, t, bs, ctx) -> int:
        if not self.colocate or self.role == "decode":
            return 0
        if self.preempt_deadline >= 0:
            # preemption notice: the job committed its final checkpoint in
            # begin_preempt and stops — remaining rounds drain decode only
            return 0
        if self.ft_breaker and self.role == "colocated":
            # fleet past QoS headroom: every colocated quantum yields to
            # inference until the breaker resets. Dedicated finetune
            # instances are exempt — pausing them frees no decode capacity
            if bs > 0:
                self.ft.stall_rounds += 1
            return 0
        if self.ckpt is not None:
            if self.ckpt.busy(t):
                # the commit's device->host stream occupies the finetune
                # side of the round: quantum 0, charged as a stall
                if bs > 0:
                    self.ft.stall_rounds += 1
                return 0
            if self.ckpt.maybe_commit(t, self.ft.units_done):
                if bs > 0:
                    self.ft.stall_rounds += 1
                return 0
        if self.straggler.suppress_quantum and bs > 0:
            self.ft.stall_rounds += 1
            return 0
        avail = self.ft.units_available(t, self.sim.k_max)
        if avail == 0:
            if bs > 0:
                self.ft.stall_rounds += 1
            return 0
        if self.role == "finetune" or \
                (self.sched is None and self.sim.mode != "static"):
            # dedicated ft instance (or no QoS scheduler fitted, e.g. the
            # separate-mode ft instance): free-run only while idle
            return self.sim.k_max if bs == 0 else 0
        if self.sim.mode == "static":
            return min(int(round(self.sim.static_quantum * self.sim.k_max)),
                       avail)
        d = self.sched.pick(bs, ctx, ft_ready=avail > 0,
                            ft_units_available=avail)
        return d.k

    # -- chunked prefill --------------------------------------------------
    def _fused_chunk_k(self, bs: int, ctx: float, chunk_tokens: int,
                       takes: List[Tuple[Request, int]]) -> int:
        """Finetune quantum to fuse into a chunk-carrying round. 0 unless
        ``ChunkedPrefillConfig.fuse_quantum`` is on AND the predictor's
        fused mixed stage (fit over q_ft>0 rounds, ``fit_mixed_fused``)
        prices a reduced quantum + the chunk as jointly fitting
        ``qos_margin * TPOT-SLO`` — then the largest such quantum runs
        alongside the chunk instead of being preempted outright."""
        if not self.chunked.fuse_quantum or not self.colocate \
                or self.role != "colocated" or self.sched is None \
                or self.predictor is None \
                or self.predictor.mixed_fused_coef is None \
                or self.straggler.suppress_quantum:
            return 0
        # TTFT guard: fuse only when this round's chunk drains the whole
        # arrived prefill queue. Under backlog every extra round-ms delays
        # queued first tokens (inference > finetune, §2.3) — the fused
        # quantum harvests rounds whose chunk work is the queue's tail
        covered = {r.rid: tok for r, tok in takes}
        for arr, _, r in self._chunk_pending:
            if arr > self.t:
                continue
            if r.effective_prompt_len - r.prefilled_tokens \
                    - covered.get(r.rid, 0) > 0:
                return 0
        avail = self.ft.units_available(self.t, self.sim.k_max)
        limit = self.sim.qos_s * self.chunked.qos_margin
        for k in range(min(avail, self.sim.k_max), 0, -1):
            if self.predictor.predict_mixed_fused(
                    k / self.sim.k_max, bs, ctx, chunk_tokens) <= limit:
                return k
        return 0

    def _chunk_qos_cap(self, bs: int, ctx: float, chunk_ctx: float) -> int:
        """Largest chunk this round may carry without the predicted round
        latency breaking the TPOT target — the prediction-driven admission
        price (paper §5 applied to chunks). Chunk rounds run at q_ft=0
        (inference work preempts finetune, §2.3). Falls back to a
        deterministic cost-model halving search when no predictor is
        fitted (e.g. separate mode)."""
        budget = self.chunk_budget
        if bs == 0:
            return budget            # no decode tokens to protect
        limit = self.sim.qos_s * self.chunked.qos_margin
        if self.predictor is not None and \
                self.predictor.mixed_coef is not None:
            return min(budget,
                       max(self.predictor.max_chunk_tokens(
                           0.0, bs, ctx, limit, budget), 0))
        tok = budget
        while tok > 0 and self.cm_inf.mixed_round_latency(
                bs, ctx, tok, chunk_ctx, noisy=False) > limit:
            tok //= 2
        return tok

    def _select_chunk(self, bs: int, ctx: float
                      ) -> Tuple[int, float, List[Tuple[Request, int]]]:
        """Plan this round's prefill chunk: FIFO over arrived pending
        requests, capped by the per-round budget and (when decode tokens
        share the round) the QoS price. Returns (tokens, mean chunk
        context, [(request, tokens taken)]); nothing is committed until
        ``_apply_chunk`` runs with the round's end time."""
        takes: List[Tuple[Request, int]] = []
        if not self.chunked or not self._chunk_pending \
                or self._chunk_pending[0][0] > self.t:
            return 0, 0.0, takes
        head = self._chunk_pending[0][2]
        left = self._chunk_qos_cap(
            bs, ctx, head.cache_hit_tokens + head.prefilled_tokens)
        total, ctx_accum = 0, 0.0
        # walk the heap in FIFO (arrival, rid) order by popping, then push
        # every popped item back — the plan usually consumes 1-2 heads, so
        # this stays O(k log n) instead of sorting the whole queue per round
        popped: List[Tuple[float, int, Request]] = []
        while self._chunk_pending and left > 0:
            item = heapq.heappop(self._chunk_pending)
            popped.append(item)
            if item[0] > self.t:
                break
            r = item[2]
            rem = r.effective_prompt_len - r.prefilled_tokens
            tok = min(rem, left)
            takes.append((r, tok))
            ctx_accum += (r.cache_hit_tokens + r.prefilled_tokens
                          + tok / 2) * tok
            total += tok
            left -= tok
        for item in popped:
            heapq.heappush(self._chunk_pending, item)
        mean_ctx = ctx_accum / total if total else 0.0
        return total, mean_ctx, takes

    def _apply_chunk(self, takes: List[Tuple[Request, int]],
                     start: float, end: float) -> None:
        """Commit a planned chunk after its round ran: advance per-request
        progress, and move fully-prefilled requests to the decode queue."""
        finished_rids = set()
        for r, tok in takes:
            if r.prefill_start < 0:
                r.prefill_start = start
            r.prefilled_tokens += tok
            if r.prefilled_tokens >= r.effective_prompt_len:
                r.prefill_done = end
                finished_rids.add(r.rid)
                self.chunk_waits.append((end, end - r.arrival))
                heapq.heappush(self._pending, (end, r.rid, r))
        if finished_rids:
            self._chunk_pending = [
                item for item in self._chunk_pending
                if item[1] not in finished_rids]
            heapq.heapify(self._chunk_pending)

    def recent_chunk_waits(self, now: float) -> List[float]:
        """Arrival -> prefill-done waits of chunked requests completed
        within the recency window (old samples are pruned). The router
        pools these fleet-wide into the TTFT-headroom p99 the autoscaler's
        chunk-budget loop reads (mirrors PrefillPool.wait_p99)."""
        if not self.chunked:
            return []
        lo = now - self.chunked.chunk_wait_window_s
        while self.chunk_waits and self.chunk_waits[0][0] < lo:
            self.chunk_waits.popleft()
        return [w for t, w in self.chunk_waits if t >= lo]

    # -- one simulation event ---------------------------------------------
    def _admit(self) -> None:
        while self._pending and self._pending[0][0] <= self.t \
                and len(self.active) < self.sim.max_slots:
            r = self._pending[0][2]
            if not self._can_admit(self.active, r):
                if not self.active and not self._can_admit([], r):
                    # can never fit even on an empty instance: drop it
                    # (finish stays -1 — routed but not completed) rather
                    # than wedge the queue head and stall the event loop
                    heapq.heappop(self._pending)
                    self.dropped += 1
                    continue
                break
            self.alloc.pressure_shrink()
            # context_len == prompt_len on first admission; a restarted
            # request (instance failure) re-allocates its full context —
            # the re-prefill regenerated prompt AND already-emitted tokens
            if not self.alloc.kv_alloc_tokens(r.context_len):
                break
            heapq.heappop(self._pending)
            if r.generated == 0:
                r.token_times.append(self.t)    # first token from prefill
                r.generated = 1
            # else: re-admitted after a failure — decode resumes at the old
            # cursor, and the kill -> re-admit gap lands between consecutive
            # token_times as the churn TPOT penalty
            self.active.append(r)
            if self.prefix_cache is not None and r.session_id >= 0:
                # the prompt KV is resident from here on: later requests
                # sharing any leading segment (same session, or a shared
                # system prompt) routed here skip prefill for the prefix
                self.prefix_cache.insert(r.session_id, r.prompt_len,
                                         segments=r.prefix_segments)

    def step(self, until: float) -> float:
        """Advance the instance clock by ONE event (an idle fast-forward, a
        finetune free-run burst, or a decode round), never starting an event
        at or beyond ``until``. Returns the new clock. A decode round that
        begins before ``until`` may finish past it (rounds are atomic)."""
        if self.t >= until:
            return self.t
        sim = self.sim
        self._admit()
        bs = len(self.active)
        ctx = (sum(r.context_len for r in self.active) / bs) if bs else 0.0
        chunk_ready = bool(self._chunk_pending) \
            and self._chunk_pending[0][0] <= self.t
        # ---- prefill-only round (chunked mode, no active decode) --------
        if bs == 0 and chunk_ready:
            tokens, chunk_ctx, takes = self._select_chunk(0, 0.0)
            if tokens > 0:
                start = self.t
                lat = self.cm_inf.mixed_round_latency(0, 0.0, tokens,
                                                      chunk_ctx)
                lat += self._adapter_load_s()
                self.t += lat
                self._apply_chunk(takes, start, self.t)
                self.chunk_timeline.append((start, tokens,
                                            self.chunk_budget))
                # a prefill round is inference work: finetune yields, but
                # its streaming channel keeps moving
                self.quantum_timeline.append((self.t, 0, lat, 0))
                if self.colocate:
                    self.ft.pump_dma(self.t)
                return self.t
        # ---- idle fast-forward ------------------------------------------
        if bs == 0:
            nxt = until
            if self._pending:
                nxt = min(self._pending[0][0], nxt)
            if self._chunk_pending:
                nxt = min(self._chunk_pending[0][0], nxt)
            if nxt <= self.t:
                # head-of-line ready but blocked (transient alloc failure):
                # with no active work nothing can unblock it before `until`,
                # so jump there instead of spinning in place
                nxt = until
            if self.colocate and self.role != "decode":
                k = self._pick_k(self.t, 0, 0.0)
                if k > 0:
                    # free-run, but stop at the next arrival (+1 unit)
                    unit = self.ft.avg_unit_time_solo()
                    if self.t + k * unit > nxt:
                        k = max(1, min(k, int((nxt - self.t) / unit) + 1))
                    lat = k * unit
                    self.ft.advance(k, self.t + lat)
                    self.quantum_timeline.append((self.t, k, lat, 0))
                    self.t += lat
                    return self.t
                # stalled on DMA: jump to DMA completion or next arrival
                self.t = min(max(self.ft.dma_busy_until, self.t + 1e-4), nxt)\
                    if self.ft.dma_busy_until > self.t else nxt
                return self.t
            self.t = nxt
            return self.t
        # ---- co-scheduled decode round ----------------------------------
        cm = self.cm_inf
        chunk_tokens, chunk_ctx, takes = (
            self._select_chunk(bs, ctx) if chunk_ready else (0, 0.0, []))
        if chunk_tokens > 0:
            # the round carries a prefill chunk: inference work preempts
            # finetune (§2.3), so the quantum is 0 — unless fuse_quantum
            # is on and the predictor prices a reduced quantum + the
            # chunk as jointly fitting the round budget. The chunk's own
            # TPOT impact was priced by _chunk_qos_cap before admission.
            k = self._fused_chunk_k(bs, ctx, chunk_tokens, takes)
            lat = cm.mixed_round_latency(
                bs, ctx, chunk_tokens, chunk_ctx, k_units=k,
                micro_batch=sim.micro_batch, seq_len=sim.ft_seq)
            expected = cm.mixed_round_latency(
                bs, ctx, chunk_tokens, chunk_ctx, k_units=k,
                micro_batch=sim.micro_batch, seq_len=sim.ft_seq,
                noisy=False)
        else:
            k = self._pick_k(self.t, bs, ctx)
            if k > 0:
                lat = cm.colocated_round(bs, ctx, k, sim.micro_batch,
                                         sim.ft_seq)
                expected = cm.colocated_round(bs, ctx, k, sim.micro_batch,
                                              sim.ft_seq, noisy=False)
            else:
                lat = cm.decode_solo(bs, ctx)
                expected = cm.decode_solo(bs, ctx, noisy=False)
        if sim.straggler_prob and self._rng.random() < sim.straggler_prob:
            lat *= float(self._rng.uniform(3.0, 8.0))   # injected fault
        # pending adapter hot-loads land in this round: the DMA time is
        # part of both actual and expected latency (a swap is planned
        # work, not a straggler signal)
        load_s = self._adapter_load_s()
        if load_s > 0.0:
            lat += load_s
            expected += load_s
        round_start = self.t
        self.t += lat
        self.rounds += 1
        self.bs_accum += bs
        self.straggler.observe(lat, expected_s=expected)
        if self.sched is not None:
            self.sched.observe(lat)
        if self.colocate and k > 0:
            self.ft.advance(k, self.t)
        elif self.colocate:
            self.ft.pump_dma(self.t)
        if chunk_tokens > 0:
            self._apply_chunk(takes, round_start, self.t)
            self.chunk_timeline.append((round_start, chunk_tokens,
                                        self.chunk_budget))
        self.quantum_timeline.append((self.t, k, lat, bs))
        self.batch_timeline.append((self.t, bs))
        # ---- token bookkeeping ------------------------------------------
        self.alloc.pressure_shrink()
        self.alloc.kv_alloc_tokens(bs)
        done = []
        for r in self.active:
            r.token_times.append(self.t)
            r.generated += 1
            if r.generated >= r.max_new_tokens:
                r.finish = self.t
                done.append(r)
        for r in done:
            self.active.remove(r)
            self.alloc.kv_free_tokens(r.context_len)
        self._snap_ctr += 1
        if self._snap_ctr % sim.snapshot_every == 0:
            self.alloc.snapshot(self.t)
        return self.t

    def _adapter_load_s(self) -> float:
        """Perform queued adapter hot-loads now; seconds to charge to the
        current round (0.0 when adapter serving is off or nothing queued)."""
        if self.adapters is None:
            return 0.0
        return self.adapters.take_load_time(self._adapters_in_use())

    def _adapters_in_use(self) -> Set[int]:
        """Adapter ids pinned by in-flight requests — never evicted."""
        ids = {r.adapter_id for r in self.active if r.adapter_id >= 0}
        ids |= {req.adapter_id for _, _, req in self._pending
                if req.adapter_id >= 0}
        ids |= {req.adapter_id for _, _, req in self._chunk_pending
                if req.adapter_id >= 0}
        return ids

    def collect_tpot(self) -> None:
        """Fold per-token latencies of every routed request into the result
        buffer (call once, after the event loop ends)."""
        for r in self.all_reqs:
            self.result_tpot.extend(r.tpot_samples())

    def run(self, reqs: List[Request], ready_times: Dict[int, float],
            duration: float) -> None:
        """Original monolithic loop, as a wrapper over enqueue/step."""
        for r in reqs:
            self.enqueue(r, ready_times[r.rid])
        while self.t < duration:
            self.step(duration)
        self.collect_tpot()


# ------------------------------------------------------------- experiment
def fit_predictor(cfg_inf: ModelConfig, sim: SimConfig):
    """Fit the harli two-stage predictor on cost-model samples, with the
    seed layout every experiment shares. Returns (predictor, fit_report);
    (None, None) for modes that don't schedule with it."""
    if sim.mode != "harli":
        return None, None
    predictor = TwoStageLatencyPredictor(k_max=sim.k_max)
    cm_fit = CostModel(cfg_inf, InstanceSpec(tp=sim.tp), seed=sim.seed + 13)
    report = predictor.fit_from_costmodel(
        cm_fit, micro_batch=sim.micro_batch, ft_seq=sim.ft_seq)
    return predictor, report


def simulate(cfg_inf: ModelConfig, cfg_ft: ModelConfig,
             reqs: List[Request], sim: SimConfig,
             duration: Optional[float] = None) -> SimResult:
    spec = InstanceSpec(tp=sim.tp)
    predictor, pred_report = fit_predictor(cfg_inf, sim)

    if sim.mode == "separate":
        instances = [
            DecodeInstanceSim(0, cfg_inf, None, sim, None, sim.seed),
            DecodeInstanceSim(1, cfg_ft, cfg_ft, sim, None, sim.seed + 1,
                              serves_inference=False),
        ]
        shares = [reqs, []]
    else:
        instances = [DecodeInstanceSim(i, cfg_inf, cfg_ft, sim, predictor,
                                       sim.seed + i)
                     for i in range(sim.n_decode_instances)]
        shares = [[] for _ in range(sim.n_decode_instances)]
        for idx, r in enumerate(sorted(reqs, key=lambda r: r.arrival)):
            shares[idx % sim.n_decode_instances].append(r)

    # one prefill instance per decode-serving instance (disaggregated pool
    # scales with decode capacity — paper §8.1 deploys PD-disaggregated)
    cm_prefill = CostModel(cfg_inf, spec, seed=sim.seed + 7)
    ready: Dict[int, float] = {}
    for share in shares:
        t_pref = 0.0
        for r in sorted(share, key=lambda r: r.arrival):
            t_pref = max(t_pref, r.arrival) + cm_prefill.prefill_latency(
                r.prompt_len)
            ready[r.rid] = t_pref
            r.prefill_done = t_pref
    duration = duration or (max(ready.values()) + 30.0 if ready else 30.0)

    for inst, share in zip(instances, shares):
        inst.run(share, ready, duration)

    res = SimResult(mode=sim.mode, duration=duration,
                    predictor_report=pred_report)
    minibatch = sim.micro_batch * sim.accum
    for inst in instances:
        if inst.ft is not None:
            res.ft_units_done += inst.ft.units_done
            res.ft_iterations += inst.ft.iterations
            res.ft_stall_rounds += inst.ft.stall_rounds
        res.tpot.extend(inst.result_tpot)
        res.decode_rounds += inst.rounds
        res.batch_timeline.extend(inst.batch_timeline)
        res.quantum_timeline = inst.quantum_timeline \
            if inst.colocate else res.quantum_timeline
        res.memory_timeline = inst.alloc.timeline \
            if inst.colocate else res.memory_timeline
    res.ft_throughput = res.ft_iterations / duration * minibatch
    res.completed = sum(1 for r in reqs if r.finish > 0)
    if res.tpot:
        viol = sum(1 for x in res.tpot if x > sim.qos_s * 1.05)
        res.qos_violation_frac = viol / len(res.tpot)
    if res.decode_rounds:
        res.mean_batch = sum(b for _, b in res.batch_timeline) \
            / max(len(res.batch_timeline), 1)
    return res
