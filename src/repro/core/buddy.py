"""Buddy allocator for the small-tensor pool (paper §4.5).

Both tasks issue thousands of sub-2MB allocations per iteration (activations,
router buffers, norms). Serving them from the 2MB-block pool would fragment
it badly, so Harli gives them a dedicated pool with 2KB granularity managed
by a classic power-of-two buddy scheme. Pure bookkeeping (offsets into a
pre-allocated region), hypothesis-tested in tests/test_allocator.py.
"""

from __future__ import annotations

from typing import Dict, List, Optional


class BuddyAllocator:
    def __init__(self, size_bytes: int, min_block: int = 2048):
        assert size_bytes % min_block == 0
        self.min_block = min_block
        # round pool down to a power-of-two multiple of min_block
        self.levels = (size_bytes // min_block).bit_length() - 1
        self.size = min_block * (1 << self.levels)
        # free lists per level: level 0 = whole pool, level L = min blocks
        self.free: List[List[int]] = [[] for _ in range(self.levels + 1)]
        self.free[0] = [0]
        self.allocated: Dict[int, int] = {}   # offset -> level
        self.allocated_bytes = 0
        self.peak_bytes = 0

    def _level_for(self, size: int) -> int:
        size = max(size, self.min_block)
        block = self.min_block * (1 << self.levels)
        lvl = 0
        while lvl < self.levels and block // 2 >= size:
            block //= 2
            lvl += 1
        return lvl

    def block_size(self, level: int) -> int:
        return self.size >> level

    def alloc(self, size: int) -> Optional[int]:
        """Returns byte offset or None if out of memory."""
        if size <= 0 or size > self.size:
            return None
        lvl = self._level_for(size)
        # find the deepest level <= lvl with a free block
        src = lvl
        while src >= 0 and not self.free[src]:
            src -= 1
        if src < 0:
            return None
        off = self.free[src].pop()
        while src < lvl:                      # split down
            src += 1
            buddy = off + self.block_size(src)
            self.free[src].append(buddy)
        self.allocated[off] = lvl
        self.allocated_bytes += self.block_size(lvl)
        self.peak_bytes = max(self.peak_bytes, self.allocated_bytes)
        return off

    def freeb(self, off: int) -> None:
        lvl = self.allocated.pop(off)
        self.allocated_bytes -= self.block_size(lvl)
        # coalesce with buddy while possible
        while lvl > 0:
            bsize = self.block_size(lvl)
            buddy = off ^ bsize
            if buddy in self.free[lvl]:
                self.free[lvl].remove(buddy)
                off = min(off, buddy)
                lvl -= 1
            else:
                break
        self.free[lvl].append(off)

    # ------------------------------------------------------- invariants --
    def check_invariants(self) -> None:
        """No overlap, full coverage. O(n log n); used by tests."""
        spans = []
        for off, lvl in self.allocated.items():
            spans.append((off, off + self.block_size(lvl), "a"))
        for lvl, offs in enumerate(self.free):
            for off in offs:
                spans.append((off, off + self.block_size(lvl), "f"))
        spans.sort()
        cursor = 0
        for lo, hi, _ in spans:
            assert lo == cursor, f"gap/overlap at {lo} (cursor {cursor})"
            cursor = hi
        assert cursor == self.size, f"coverage {cursor} != {self.size}"

    @property
    def fragmentation_bytes(self) -> int:
        """Free bytes not in the largest free block (external fragmentation)."""
        free_total = self.size - self.allocated_bytes
        largest = 0
        for lvl, offs in enumerate(self.free):
            if offs:
                largest = max(largest, self.block_size(lvl))
        return free_total - largest
