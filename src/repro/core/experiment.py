"""Unified experiment specification for the cluster control plane.

Before this module, every entry point (examples/cluster_sim.py,
benchmarks/*, tests) hand-wired four overlapping config dataclasses
(SimConfig, TraceConfig, RouterConfig, ClusterConfig) plus autoscaler
knobs, each with its own seed conventions — and contradictory
combinations (a chunk budget in pooled mode, pool workers in chained
mode) were silently ignored. ``ExperimentSpec`` is the one object that
composes all of it, with:

  * ``validate()``   — registry-checked policy names and *loud* rejection
    of contradictory mode/knob combinations, with the fix in the message;
  * ``to_json`` / ``from_json`` — loss-free round trip, so an experiment
    is a reviewable artifact (``examples/specs/*.json``) and
    ``cluster_sim.py --spec file.json`` reruns it exactly;
  * ``run()``        — the single entry point: generate the trace
    (seeded ``seed + 1``, the convention every example already used) and
    run the cluster simulation;
  * ``instance_overrides`` (on ``ClusterConfig``) — the heterogeneous-
    fleet hook, validated here: entry *i* replaces SimConfig fields for
    the *i*-th spawned instance (mixed tp, mixed slot budgets, ...).

Determinism: a spec fully determines the experiment —
``from_json(spec.to_json()).run()`` is seed-identical to ``spec.run()``
(tested), and the legacy string-kwarg construction path produces
bit-identical results (pinned per prefill mode in tests/test_api.py).
"""

from __future__ import annotations

import dataclasses
import json
import typing
from typing import Dict, List, Optional, Tuple, Union

from repro.configs import get_config
from repro.core import api
from repro.core.cluster import (ClusterConfig, ClusterResult,
                                DegradationConfig, simulate_cluster)
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.simulator import ChunkedPrefillConfig, SimConfig
from repro.serving.request import Request
from repro.serving.trace import SCENARIOS, TraceConfig, generate, \
    generate_scenario

SIM_MODES = ("harli", "static", "separate")
# per-instance override keys: any SimConfig field except the experiment
# identity ones (mode is fleet-wide; per-instance seeds derive from it)
OVERRIDABLE_SIM_FIELDS = tuple(
    f.name for f in dataclasses.fields(SimConfig)
    if f.name not in ("mode", "seed"))


class SpecError(ValueError):
    """An ExperimentSpec that cannot mean what it says — unknown names or
    contradictory knob combinations. The message states the fix."""


def _from_dict(cls, data):
    """Reconstruct a (possibly nested) config dataclass from plain dicts,
    rejecting unknown keys with the valid field names in the message."""
    if data is None:
        return None
    if not dataclasses.is_dataclass(cls):
        return data
    if isinstance(data, cls):
        return data
    if not isinstance(data, dict):
        raise SpecError(f"{cls.__name__} must be an object, "
                        f"got {type(data).__name__}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - set(fields))
    if unknown:
        raise SpecError(
            f"unknown {cls.__name__} field(s) {', '.join(unknown)}; "
            f"valid fields: {', '.join(sorted(fields))}")
    hints = typing.get_type_hints(cls)
    kwargs = {}
    for name, value in data.items():
        t = hints[name]
        origin = typing.get_origin(t)
        if origin is Union:                      # Optional[X]
            args = [a for a in typing.get_args(t) if a is not type(None)]
            t, origin = args[0], typing.get_origin(args[0])
        if dataclasses.is_dataclass(t):
            value = _from_dict(t, value)
        elif origin is tuple and value is not None:
            value = tuple(value)
        kwargs[name] = value
    return cls(**kwargs)


@dataclasses.dataclass
class ExperimentSpec:
    """One cluster experiment, fully specified and JSON-serializable.

    ``seed`` is the experiment's base seed: the trace draws at
    ``seed + 1`` (the convention examples/cluster_sim.py always used);
    ``sim.seed`` / ``cluster.router.seed`` keep their own threading
    (router derives from sim unless explicit). ``trace`` overrides the
    scenario preset entirely when given."""

    name: str = "experiment"
    inf_model: str = "llama3-8b"         # serving model config name
    ft_model: str = "llama3-8b"          # finetune model config name
    scenario: str = "spike"              # trace preset (serving/trace.py)
    duration_s: float = 60.0
    mean_rps: float = 10.0
    n_sessions: int = 0                  # sticky sessions in the trace
    seed: int = 0
    trace: Optional[TraceConfig] = None  # full trace override
    sim: SimConfig = dataclasses.field(default_factory=SimConfig)
    cluster: ClusterConfig = dataclasses.field(default_factory=ClusterConfig)

    # ------------------------------------------------------- validation --
    def validate(self) -> "ExperimentSpec":
        """Raise ``SpecError`` on unknown names or contradictory knob
        combinations (the centralized check examples/cluster_sim.py and
        ``run()`` both go through). Returns self for chaining."""
        for role, model in (("inf_model", self.inf_model),
                            ("ft_model", self.ft_model)):
            try:
                get_config(model)
            except Exception as e:
                raise SpecError(f"{role}={model!r}: {e}") from None
        if self.trace is None and self.scenario not in SCENARIOS:
            raise SpecError(f"unknown scenario {self.scenario!r}; choose "
                            f"from {', '.join(SCENARIOS)}")
        if self.trace is not None:
            # with a full trace override the top-level trace-shape fields
            # must mirror it — they feed reports and duration scaling, and
            # a silent disagreement is exactly the ignored-knob class this
            # method exists to reject
            for fld, tval in (("duration_s", self.trace.duration_s),
                              ("mean_rps", self.trace.mean_rps),
                              ("n_sessions", self.trace.n_sessions)):
                if getattr(self, fld) != tval:
                    raise SpecError(
                        f"trace override is set but {fld}="
                        f"{getattr(self, fld)} disagrees with trace.{fld}"
                        f"={tval} — set both to the same value (the trace"
                        " is what actually runs)")
        if self.duration_s <= 0 or self.mean_rps <= 0:
            raise SpecError("duration_s and mean_rps must be positive")
        if self.n_sessions < 0:
            raise SpecError("n_sessions must be >= 0")
        if self.sim.mode not in SIM_MODES:
            raise SpecError(f"unknown sim.mode {self.sim.mode!r}; choose "
                            f"from {', '.join(SIM_MODES)}")
        cl = self.cluster
        if cl.n_initial < 1:
            raise SpecError("cluster.n_initial must be >= 1")
        # registry-checked names: routing policy, prefill placement and
        # all three scaling-loop policies must resolve
        try:
            api.resolve_policy("routing", cl.router.policy)
            mode = cl.resolved_mode()
            for loop in ("decode_policy", "prefill_policy", "chunk_policy"):
                api.resolve_policy("scaling", getattr(cl.autoscaler, loop))
        except api.PolicyNotFoundError as e:
            raise SpecError(str(e)) from None
        # contradictory mode/knob combinations: a knob configured away
        # from its default for a mode that cannot read it is a silent
        # no-op — reject it loudly instead
        if mode == "pooled" and cl.prefill is None:
            raise SpecError(
                "prefill_mode 'pooled' needs a prefill pool config "
                "(cluster.prefill / --prefill-workers >= 1)")
        if mode != "pooled" and cl.prefill is not None \
                and cl.prefill != PrefillPoolConfig():
            raise SpecError(
                f"prefill pool configured ({cl.prefill}) but prefill_mode "
                f"is {mode!r} — the pool only exists in pooled mode; drop "
                "the pool config (CLI: --prefill-workers only applies to "
                "--prefill-mode pooled)")
        if mode != "chunked" and cl.chunked != ChunkedPrefillConfig():
            raise SpecError(
                f"chunked-prefill knobs configured ({cl.chunked}) but "
                f"prefill_mode is {mode!r} — they only apply in chunked "
                "mode; drop them (CLI: --chunk-budget / --fuse-quantum "
                "only apply to --prefill-mode chunked)")
        if cl.prefix_cache is not None and self.n_sessions == 0 \
                and self.scenario != "session_heavy":
            # session_heavy defaults its own sessions on; any other
            # sessionless trace would make the cache pure cost — it
            # reserves real allocator capacity (shrinking the finetune
            # window and KV budget) and can never hit
            raise SpecError(
                "prefix_cache configured but the trace is sessionless "
                "(n_sessions=0) — the session-keyed cache would reserve "
                "allocator capacity and never hit; set n_sessions > 0 "
                "or drop prefix_cache")
        if cl.failures is not None:
            f = cl.failures
            if f.rate_per_min < 0 or f.warning_s < 0 or f.start_s < 0 \
                    or f.checkpoint_interval_s < 0:
                raise SpecError(
                    "cluster.failures knobs (rate_per_min, warning_s, "
                    "start_s, checkpoint_interval_s) must all be >= 0")
            if f.rate_per_min == 0 and f.warning_s == 0 \
                    and f.checkpoint_interval_s == 0:
                raise SpecError(
                    "cluster.failures is configured but fully inert "
                    "(rate 0, no warning, no checkpointing) — drop it "
                    "(failures: null) to state the fleet is stable")
        if cl.migration is not None:
            m = cl.migration
            if cl.failures is None:
                raise SpecError(
                    "cluster.migration is configured but failures is null "
                    "— live KV migration only fires on preemption "
                    "warnings; configure cluster.failures (CLI: "
                    "--migration-bw requires --churn-rate > 0)")
            if cl.failures.warning_s <= 0:
                raise SpecError(
                    "cluster.migration is configured but failures."
                    "warning_s is 0 — hard kills leave no window to "
                    "stream KV; set warning_s > 0 (CLI: --churn-warning)")
            if m.bw_gbps <= 0:
                raise SpecError(
                    "cluster.migration.bw_gbps must be > 0 — a zero-"
                    "bandwidth link can never move KV; drop the config "
                    "(migration: null) to state re-prefill-only intent")
            if m.setup_s < 0:
                raise SpecError("cluster.migration.setup_s must be >= 0")
            try:
                api.resolve_policy("migration", m.policy)
            except api.PolicyNotFoundError as e:
                raise SpecError(str(e)) from None
        if cl.degradation is not None:
            g = cl.degradation
            if not (0.0 <= g.resume_viol_frac <= g.breaker_viol_frac
                    <= g.shed_viol_frac <= 1.0):
                raise SpecError(
                    "cluster.degradation thresholds must satisfy 0 <= "
                    "resume_viol_frac <= breaker_viol_frac <= "
                    "shed_viol_frac <= 1 — the ladder escalates through "
                    f"them in order (got resume={g.resume_viol_frac}, "
                    f"breaker={g.breaker_viol_frac}, "
                    f"shed={g.shed_viol_frac})")
            if g.backoff_base_s <= 0 or g.backoff_mult < 1.0 \
                    or not (0.0 <= g.backoff_jitter < 1.0) \
                    or g.max_retries < 0:
                raise SpecError(
                    "cluster.degradation backoff knobs out of range: "
                    "backoff_base_s > 0, backoff_mult >= 1, 0 <= "
                    "backoff_jitter < 1, max_retries >= 0")
            if not g.shed:
                base = DegradationConfig(shed=False)
                tuned = [k for k in ("shed_viol_frac", "backoff_base_s",
                                     "backoff_mult", "backoff_jitter",
                                     "max_retries", "seed")
                         if getattr(g, k) != getattr(base, k)]
                if tuned:
                    raise SpecError(
                        f"cluster.degradation.shed is false but shedding "
                        f"knob(s) {', '.join(tuned)} are configured — "
                        "they only apply when shedding is enabled; drop "
                        "them or set shed: true (CLI: --shed-* flags "
                        "require the ladder with shedding on)")
        for i, ov in enumerate(cl.instance_overrides):
            if not isinstance(ov, dict):
                raise SpecError(f"instance_overrides[{i}] must be an "
                                "object of SimConfig fields")
            bad = sorted(set(ov) - set(OVERRIDABLE_SIM_FIELDS))
            if bad:
                raise SpecError(
                    f"instance_overrides[{i}] has non-overridable "
                    f"field(s) {', '.join(bad)}; overridable: "
                    f"{', '.join(OVERRIDABLE_SIM_FIELDS)}")
        return self

    # ------------------------------------------------------------- JSON --
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        # normalize: a *default* pool config outside pooled mode is just
        # the dataclass default riding along — write null so the JSON
        # artifact states only what the experiment reads
        mode = self.cluster.prefill_mode
        if mode is None:
            mode = "pooled" if self.cluster.prefill is not None \
                else "chained"
        if mode != "pooled" and self.cluster.prefill == PrefillPoolConfig():
            d["cluster"]["prefill"] = None
        return d

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, data: Dict) -> "ExperimentSpec":
        return _from_dict(cls, data)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        try:
            data = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"spec is not valid JSON: {e}") from None
        return cls.from_dict(data)

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # -------------------------------------------------------------- run --
    def with_mode(self, sim_mode: str) -> "ExperimentSpec":
        """Copy of this spec with ``sim.mode`` replaced (harli-vs-separate
        comparisons run the same spec under both)."""
        return dataclasses.replace(
            self, sim=dataclasses.replace(self.sim, mode=sim_mode))

    def requests(self) -> List[Request]:
        """The (seeded, deterministic) trace this spec describes."""
        if self.trace is not None:
            return generate(self.trace)
        return generate_scenario(self.scenario, self.duration_s,
                                 self.mean_rps, seed=self.seed + 1,
                                 n_sessions=self.n_sessions)

    def run(self, duration: Optional[float] = None) -> ClusterResult:
        """Validate, generate the trace, run the cluster experiment.
        Deterministic: same spec (same JSON) -> same ClusterResult."""
        self.validate()
        cfg_inf = get_config(self.inf_model)
        cfg_ft = get_config(self.ft_model)
        return simulate_cluster(cfg_inf, cfg_ft, self.requests(),
                                self.sim, self.cluster, duration)
