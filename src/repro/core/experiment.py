"""Unified experiment specification for the cluster control plane.

Before this module, every entry point (examples/cluster_sim.py,
benchmarks/*, tests) hand-wired four overlapping config dataclasses
(SimConfig, TraceConfig, RouterConfig, ClusterConfig) plus autoscaler
knobs, each with its own seed conventions — and contradictory
combinations (a chunk budget in pooled mode, pool workers in chained
mode) were silently ignored. ``ExperimentSpec`` is the one object that
composes all of it, with:

  * ``validate()``   — registry-checked policy names and *loud* rejection
    of contradictory mode/knob combinations, with the fix in the message;
  * ``to_json`` / ``from_json`` — loss-free round trip, so an experiment
    is a reviewable artifact (``examples/specs/*.json``) and
    ``cluster_sim.py --spec file.json`` reruns it exactly; documents are
    versioned (``schema_version``, see ``SCHEMA_VERSION``) — v1 specs
    upgrade automatically through ``upgrade_v1``, unknown versions fail
    loudly listing the supported ones;
  * ``run()``        — the single entry point: generate the trace
    (seeded ``seed + 1``, the convention every example already used) and
    run the cluster simulation;
  * ``instance_overrides`` (on ``ClusterConfig``) — the heterogeneous-
    fleet hook, validated here: entry *i* replaces SimConfig fields for
    the *i*-th spawned instance (mixed tp, mixed slot budgets, ...).

Determinism: a spec fully determines the experiment —
``from_json(spec.to_json()).run()`` is seed-identical to ``spec.run()``
(tested), and the legacy string-kwarg construction path produces
bit-identical results (pinned per prefill mode in tests/test_api.py).
"""

from __future__ import annotations

import dataclasses
import json
import typing
from typing import Dict, List, Optional, Tuple, Union

from repro.configs import get_config
from repro.core import api
from repro.core import gossip as gossip_mod
from repro.core.adapters import TenantConfig
from repro.core.cluster import (ClusterConfig, ClusterResult,
                                DegradationConfig, simulate_cluster)
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.simulator import ChunkedPrefillConfig, SimConfig
from repro.serving.request import Request
from repro.serving.trace import SCENARIOS, TraceConfig, generate, \
    generate_scenario

SIM_MODES = ("harli", "static", "separate")
# JSON schema versioning: v1 is the PR-5 schema (no multi-LoRA blocks);
# v2 added the top-level ``tenants`` and ``cluster.adapters`` blocks.
# ``from_dict`` accepts both — v1 documents are upgraded in exactly one
# place (``upgrade_v1``) — and rejects anything else loudly.
SCHEMA_VERSION = 2
SUPPORTED_SCHEMA_VERSIONS = (1, 2)
# per-instance override keys: any SimConfig field except the experiment
# identity ones (mode is fleet-wide; per-instance seeds derive from it)
OVERRIDABLE_SIM_FIELDS = tuple(
    f.name for f in dataclasses.fields(SimConfig)
    if f.name not in ("mode", "seed"))


class SpecError(ValueError):
    """An ExperimentSpec that cannot mean what it says — unknown names or
    contradictory knob combinations. The message states the fix."""


def _from_dict(cls, data):
    """Reconstruct a (possibly nested) config dataclass from plain dicts,
    rejecting unknown keys with the valid field names in the message."""
    if data is None:
        return None
    if not dataclasses.is_dataclass(cls):
        return data
    if isinstance(data, cls):
        return data
    if not isinstance(data, dict):
        raise SpecError(f"{cls.__name__} must be an object, "
                        f"got {type(data).__name__}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - set(fields))
    if unknown:
        raise SpecError(
            f"unknown {cls.__name__} field(s) {', '.join(unknown)}; "
            f"valid fields: {', '.join(sorted(fields))}")
    hints = typing.get_type_hints(cls)
    kwargs = {}
    for name, value in data.items():
        t = hints[name]
        origin = typing.get_origin(t)
        if origin is Union:                      # Optional[X]
            args = [a for a in typing.get_args(t) if a is not type(None)]
            t, origin = args[0], typing.get_origin(args[0])
        if dataclasses.is_dataclass(t):
            value = _from_dict(t, value)
        elif origin is tuple and value is not None:
            args = typing.get_args(t)
            el = args[0] if args else None
            if el is not None and dataclasses.is_dataclass(el):
                value = tuple(_from_dict(el, v) for v in value)
            else:
                value = tuple(value)
        kwargs[name] = value
    return cls(**kwargs)


def upgrade_v1(data: Dict) -> Dict:
    """THE v1 -> v2 schema upgrade — the single place version migration
    happens (``from_dict`` routes every v1 document here).

    v2 added the multi-LoRA serving blocks (top-level ``tenants``,
    ``cluster.adapters``) and later the cache-gossip plane
    (``cluster.gossip``). A v1 document (``schema_version`` absent or 1)
    predates all of them, so the upgrade is: reject documents that
    smuggle v2 blocks without declaring the version, then fill the v2
    defaults (no tenants, no adapter serving, no gossip) — semantics
    unchanged by construction."""
    v2_only = [k for k in ("tenants",) if k in data]
    cl = data.get("cluster")
    if isinstance(cl, dict):
        for blk in ("adapters", "gossip"):
            if cl.get(blk) is not None:
                v2_only.append(f"cluster.{blk}")
    if v2_only:
        raise SpecError(
            f"v1 spec uses v2-only block(s) {', '.join(v2_only)} — "
            'declare "schema_version": 2')
    out = dict(data)
    out.pop("schema_version", None)
    return out


@dataclasses.dataclass
class ExperimentSpec:
    """One cluster experiment, fully specified and JSON-serializable.

    ``seed`` is the experiment's base seed: the trace draws at
    ``seed + 1`` (the convention examples/cluster_sim.py always used);
    ``sim.seed`` / ``cluster.router.seed`` keep their own threading
    (router derives from sim unless explicit). ``trace`` overrides the
    scenario preset entirely when given."""

    schema_version: int = SCHEMA_VERSION
    name: str = "experiment"
    inf_model: str = "llama3-8b"         # serving model config name
    ft_model: str = "llama3-8b"          # finetune model config name
    scenario: str = "spike"              # trace preset (serving/trace.py)
    duration_s: float = 60.0
    mean_rps: float = 10.0
    n_sessions: int = 0                  # sticky sessions in the trace
    seed: int = 0
    trace: Optional[TraceConfig] = None  # full trace override
    # v2: per-tenant traffic mix + SLO overrides; entry i is adapter_id i.
    # Tenants alone give per-tenant accounting (base-model serving);
    # pairing them with cluster.adapters turns on multi-LoRA serving.
    tenants: Tuple[TenantConfig, ...] = ()
    sim: SimConfig = dataclasses.field(default_factory=SimConfig)
    cluster: ClusterConfig = dataclasses.field(default_factory=ClusterConfig)

    # ------------------------------------------------------- validation --
    def validate(self) -> "ExperimentSpec":
        """Raise ``SpecError`` on unknown names or contradictory knob
        combinations (the centralized check examples/cluster_sim.py and
        ``run()`` both go through). Returns self for chaining."""
        for role, model in (("inf_model", self.inf_model),
                            ("ft_model", self.ft_model)):
            try:
                get_config(model)
            except Exception as e:
                raise SpecError(f"{role}={model!r}: {e}") from None
        if self.trace is None and self.scenario not in SCENARIOS:
            raise SpecError(f"unknown scenario {self.scenario!r}; choose "
                            f"from {', '.join(SCENARIOS)}")
        if self.trace is not None:
            # with a full trace override the top-level trace-shape fields
            # must mirror it — they feed reports and duration scaling, and
            # a silent disagreement is exactly the ignored-knob class this
            # method exists to reject
            for fld, tval in (("duration_s", self.trace.duration_s),
                              ("mean_rps", self.trace.mean_rps),
                              ("n_sessions", self.trace.n_sessions)):
                if getattr(self, fld) != tval:
                    raise SpecError(
                        f"trace override is set but {fld}="
                        f"{getattr(self, fld)} disagrees with trace.{fld}"
                        f"={tval} — set both to the same value (the trace"
                        " is what actually runs)")
        if self.duration_s <= 0 or self.mean_rps <= 0:
            raise SpecError("duration_s and mean_rps must be positive")
        if self.n_sessions < 0:
            raise SpecError("n_sessions must be >= 0")
        if self.sim.mode not in SIM_MODES:
            raise SpecError(f"unknown sim.mode {self.sim.mode!r}; choose "
                            f"from {', '.join(SIM_MODES)}")
        cl = self.cluster
        if cl.n_initial < 1:
            raise SpecError("cluster.n_initial must be >= 1")
        # registry-checked names: routing policy, prefill placement and
        # all three scaling-loop policies must resolve
        try:
            api.resolve_policy("routing", cl.router.policy)
            mode = cl.resolved_mode()
            for loop in ("decode_policy", "prefill_policy", "chunk_policy"):
                api.resolve_policy("scaling", getattr(cl.autoscaler, loop))
        except api.PolicyNotFoundError as e:
            raise SpecError(str(e)) from None
        # contradictory mode/knob combinations: a knob configured away
        # from its default for a mode that cannot read it is a silent
        # no-op — reject it loudly instead
        if mode == "pooled" and cl.prefill is None:
            raise SpecError(
                "prefill_mode 'pooled' needs a prefill pool config "
                "(cluster.prefill / --prefill-workers >= 1)")
        if mode != "pooled" and cl.prefill is not None \
                and cl.prefill != PrefillPoolConfig():
            raise SpecError(
                f"prefill pool configured ({cl.prefill}) but prefill_mode "
                f"is {mode!r} — the pool only exists in pooled mode; drop "
                "the pool config (CLI: --prefill-workers only applies to "
                "--prefill-mode pooled)")
        if mode != "chunked" and cl.chunked != ChunkedPrefillConfig():
            raise SpecError(
                f"chunked-prefill knobs configured ({cl.chunked}) but "
                f"prefill_mode is {mode!r} — they only apply in chunked "
                "mode; drop them (CLI: --chunk-budget / --fuse-quantum "
                "only apply to --prefill-mode chunked)")
        if cl.prefix_cache is not None and self.n_sessions == 0 \
                and self.scenario not in ("session_heavy", "shared_prefix"):
            # session_heavy/shared_prefix default their own sessions on;
            # any other sessionless trace would make the cache pure cost
            # — it reserves real allocator capacity (shrinking the
            # finetune window and KV budget) and can never hit
            raise SpecError(
                "prefix_cache configured but the trace is sessionless "
                "(n_sessions=0) — the prefix cache would reserve "
                "allocator capacity and never hit; set n_sessions > 0 "
                "or drop prefix_cache")
        if cl.gossip is not None:
            g = cl.gossip
            if cl.prefix_cache is None:
                raise SpecError(
                    "cluster.gossip configured but prefix_cache is null — "
                    "the gossip plane publishes prefix-cache digests; "
                    "configure cluster.prefix_cache or drop gossip "
                    "(gossip: null)")
            if g.period_s <= 0:
                raise SpecError("cluster.gossip.period_s must be > 0")
            if g.staleness_bound_s < g.period_s:
                raise SpecError(
                    "cluster.gossip.staleness_bound_s must be >= period_s "
                    "— a bound under the publish period would discard "
                    "every digest before its refresh arrives (got "
                    f"period={g.period_s}, bound={g.staleness_bound_s})")
            if g.top_k < 1:
                raise SpecError("cluster.gossip.top_k must be >= 1")
            if g.effective_top_k() < 1:
                raise SpecError(
                    f"cluster.gossip.max_bytes={g.max_bytes} cannot fit "
                    "even one digest entry (header "
                    f"{gossip_mod.DIGEST_HEADER_BYTES} + entry "
                    f"{gossip_mod.DIGEST_ENTRY_BYTES} bytes); raise "
                    "max_bytes")
        if cl.router.policy == "cache_aware_gossip" and cl.gossip is None:
            raise SpecError(
                "router.policy 'cache_aware_gossip' needs the gossip "
                "plane — configure cluster.gossip (it never falls back "
                "to synchronous cache peeks)")
        if cl.failures is not None:
            f = cl.failures
            if f.rate_per_min < 0 or f.warning_s < 0 or f.start_s < 0 \
                    or f.checkpoint_interval_s < 0:
                raise SpecError(
                    "cluster.failures knobs (rate_per_min, warning_s, "
                    "start_s, checkpoint_interval_s) must all be >= 0")
            if f.rate_per_min == 0 and f.warning_s == 0 \
                    and f.checkpoint_interval_s == 0:
                raise SpecError(
                    "cluster.failures is configured but fully inert "
                    "(rate 0, no warning, no checkpointing) — drop it "
                    "(failures: null) to state the fleet is stable")
        if cl.migration is not None:
            m = cl.migration
            if cl.failures is None:
                raise SpecError(
                    "cluster.migration is configured but failures is null "
                    "— live KV migration only fires on preemption "
                    "warnings; configure cluster.failures (CLI: "
                    "--migration-bw requires --churn-rate > 0)")
            if cl.failures.warning_s <= 0:
                raise SpecError(
                    "cluster.migration is configured but failures."
                    "warning_s is 0 — hard kills leave no window to "
                    "stream KV; set warning_s > 0 (CLI: --churn-warning)")
            if m.bw_gbps <= 0:
                raise SpecError(
                    "cluster.migration.bw_gbps must be > 0 — a zero-"
                    "bandwidth link can never move KV; drop the config "
                    "(migration: null) to state re-prefill-only intent")
            if m.setup_s < 0:
                raise SpecError("cluster.migration.setup_s must be >= 0")
            try:
                api.resolve_policy("migration", m.policy)
            except api.PolicyNotFoundError as e:
                raise SpecError(str(e)) from None
        if cl.degradation is not None:
            g = cl.degradation
            if not (0.0 <= g.resume_viol_frac <= g.breaker_viol_frac
                    <= g.shed_viol_frac <= 1.0):
                raise SpecError(
                    "cluster.degradation thresholds must satisfy 0 <= "
                    "resume_viol_frac <= breaker_viol_frac <= "
                    "shed_viol_frac <= 1 — the ladder escalates through "
                    f"them in order (got resume={g.resume_viol_frac}, "
                    f"breaker={g.breaker_viol_frac}, "
                    f"shed={g.shed_viol_frac})")
            if g.backoff_base_s <= 0 or g.backoff_mult < 1.0 \
                    or not (0.0 <= g.backoff_jitter < 1.0) \
                    or g.max_retries < 0:
                raise SpecError(
                    "cluster.degradation backoff knobs out of range: "
                    "backoff_base_s > 0, backoff_mult >= 1, 0 <= "
                    "backoff_jitter < 1, max_retries >= 0")
            if not g.shed:
                base = DegradationConfig(shed=False)
                tuned = [k for k in ("shed_viol_frac", "backoff_base_s",
                                     "backoff_mult", "backoff_jitter",
                                     "max_retries", "seed")
                         if getattr(g, k) != getattr(base, k)]
                if tuned:
                    raise SpecError(
                        f"cluster.degradation.shed is false but shedding "
                        f"knob(s) {', '.join(tuned)} are configured — "
                        "they only apply when shedding is enabled; drop "
                        "them or set shed: true (CLI: --shed-* flags "
                        "require the ladder with shedding on)")
        if self.schema_version != SCHEMA_VERSION:
            raise SpecError(
                f"schema_version must be {SCHEMA_VERSION} on a parsed "
                "spec — from_json/from_dict auto-upgrade v1 documents; "
                "don't set the field by hand")
        for i, tn in enumerate(self.tenants):
            if tn.weight <= 0:
                raise SpecError(f"tenants[{i}].weight must be > 0 "
                                f"(got {tn.weight})")
            for fld in ("ttft_slo_s", "tpot_slo_s"):
                v = getattr(tn, fld)
                if v is not None and v <= 0:
                    raise SpecError(
                        f"tenants[{i}].{fld} must be > 0 or null "
                        "(null inherits the fleet SLO)")
        if self.tenants and self.trace is not None \
                and tuple(self.trace.tenant_weights) \
                != tuple(t.weight for t in self.tenants):
            raise SpecError(
                "tenants block disagrees with trace.tenant_weights="
                f"{self.trace.tenant_weights} — with a full trace "
                "override, trace.tenant_weights must mirror the tenant "
                "weights (the trace is what actually runs)")
        if cl.adapters is not None:
            a = cl.adapters
            if not self.tenants and not (
                    self.trace is not None and self.trace.tenant_weights):
                raise SpecError(
                    "cluster.adapters configured but no tenant traffic — "
                    "no request would ever carry an adapter_id; add a "
                    "tenants block (or trace.tenant_weights) or drop "
                    "adapters (adapters: null)")
            if a.rank < 1:
                raise SpecError("cluster.adapters.rank must be >= 1")
            if a.publish_every_iters <= 0:
                raise SpecError(
                    "cluster.adapters.publish_every_iters must be > 0 — "
                    "it is the finetune-iterations-per-version cadence")
            if a.max_loaded < 0:
                raise SpecError("cluster.adapters.max_loaded must be >= 0 "
                                "(0 = bounded only by allocator capacity)")
            try:
                api.resolve_policy("adapter_placement", a.policy)
            except api.PolicyNotFoundError as e:
                raise SpecError(str(e)) from None
        for i, ov in enumerate(cl.instance_overrides):
            if not isinstance(ov, dict):
                raise SpecError(f"instance_overrides[{i}] must be an "
                                "object of SimConfig fields")
            bad = sorted(set(ov) - set(OVERRIDABLE_SIM_FIELDS))
            if bad:
                raise SpecError(
                    f"instance_overrides[{i}] has non-overridable "
                    f"field(s) {', '.join(bad)}; overridable: "
                    f"{', '.join(OVERRIDABLE_SIM_FIELDS)}")
        return self

    # ------------------------------------------------------------- JSON --
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        # normalize: a *default* pool config outside pooled mode is just
        # the dataclass default riding along — write null so the JSON
        # artifact states only what the experiment reads
        mode = self.cluster.prefill_mode
        if mode is None:
            mode = "pooled" if self.cluster.prefill is not None \
                else "chained"
        if mode != "pooled" and self.cluster.prefill == PrefillPoolConfig():
            d["cluster"]["prefill"] = None
        return d

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, data: Dict) -> "ExperimentSpec":
        if not isinstance(data, dict):
            raise SpecError("ExperimentSpec must be an object, "
                            f"got {type(data).__name__}")
        version = data.get("schema_version", 1)
        if version not in SUPPORTED_SCHEMA_VERSIONS:
            raise SpecError(
                f"unsupported schema_version {version!r}; supported "
                "versions: 1 (auto-upgraded), 2 (current)")
        if version == 1:
            data = upgrade_v1(data)
        return _from_dict(cls, data)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        try:
            data = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"spec is not valid JSON: {e}") from None
        return cls.from_dict(data)

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # -------------------------------------------------------------- run --
    def with_mode(self, sim_mode: str) -> "ExperimentSpec":
        """Copy of this spec with ``sim.mode`` replaced (harli-vs-separate
        comparisons run the same spec under both)."""
        return dataclasses.replace(
            self, sim=dataclasses.replace(self.sim, mode=sim_mode))

    def requests(self) -> List[Request]:
        """The (seeded, deterministic) trace this spec describes.
        When tenants are declared, their weights drive the per-request
        adapter_id draw and their SLO overrides are stamped onto each
        request (null inherits the fleet-wide router SLO)."""
        if self.trace is not None:
            reqs = generate(self.trace)
        else:
            reqs = generate_scenario(
                self.scenario, self.duration_s, self.mean_rps,
                seed=self.seed + 1, n_sessions=self.n_sessions,
                tenant_weights=tuple(t.weight for t in self.tenants))
        if self.tenants:
            for r in reqs:
                if 0 <= r.adapter_id < len(self.tenants):
                    tn = self.tenants[r.adapter_id]
                    r.ttft_slo_s = tn.ttft_slo_s
                    r.tpot_slo_s = tn.tpot_slo_s
        return reqs

    def run(self, duration: Optional[float] = None) -> ClusterResult:
        """Validate, generate the trace, run the cluster experiment.
        Deterministic: same spec (same JSON) -> same ClusterResult."""
        self.validate()
        cfg_inf = get_config(self.inf_model)
        cfg_ft = get_config(self.ft_model)
        return simulate_cluster(cfg_inf, cfg_ft, self.requests(),
                                self.sim, self.cluster, duration)
