"""Built-in live-KV-migration destination policies (survivability layer,
core/cluster.py ``KVMigrationConfig.policy``).

When an instance receives a spot-style preemption warning, the cluster
loop streams each victim request's KV to a peer over the interconnect;
these policies pick the peer. They must be deterministic — migration
runs on the seeded failure path and feeds the churn bit-identity tests.
"""

from __future__ import annotations

from repro.core.api import MigrationPolicy, register_policy


@register_policy("kv_headroom")
class KVHeadroomDest(MigrationPolicy):
    """Most free KV admission budget under the unified allocator's
    conservative reservation (``DecodeInstanceSim.kv_headroom_chunks``):
    the migrated context must be admitted on arrival, so headroom — not
    queue length — is the binding constraint. Load and instance id break
    ties deterministically."""

    def pick_dest(self, req, cand, router):
        return max(cand, key=lambda i: (i.kv_headroom_chunks(),
                                        -i.load(), -i.inst_id))


@register_policy("least_loaded")
class LeastLoadedDest(MigrationPolicy):
    """Join-shortest-queue on the occupancy signal — the same heuristic
    as the routing-kind ``least_loaded`` (per-kind namespaces let the
    name be reused). Ignores KV headroom, so a lightly-loaded but
    memory-full peer can stall the migrated request at admission; kept
    as the comparison baseline for ``kv_headroom``."""

    def pick_dest(self, req, cand, router):
        return min(cand, key=lambda i: (i.load(), i.inst_id))
