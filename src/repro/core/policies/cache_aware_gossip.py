"""``cache_aware_gossip`` routing: digest-scored cache-aware placement.

``cache_aware`` pays one synchronous ``PrefixCache.peek`` per candidate
per dispatch — O(fleet) cache probes per request, a control-plane cost
that does not survive fleets well beyond 16 instances. This policy makes
the same placement decision from the asynchronous gossip plane
(core/gossip.py) instead: each instance's cache publishes a compact
digest (top-k prefix fingerprints + cached token counts) on a period,
and the dispatch path reads only those digests — **zero synchronous
cache peeks** (``router.dispatch_peeks`` stays 0, tested).

The estimated hit for a candidate is the deepest digest entry whose
fingerprint matches a prefix of the request's segment path, capped by
the request's own depth there, floored by the cache's min-hit threshold
and then discounted by digest age: a digest near the staleness bound may
advertise KV that has since been evicted, so its promise is worth
proportionally less (``GossipPlane.discount``, linear to 0 at the
bound). A missing or over-age digest scores as a cold cache — the policy
never falls back to a synchronous peek.

Score shape and tie-breaking are identical to ``cache_aware``; with a
fresh, complete digest the two policies make the same choice (the
decision table is in docs/cluster.md)."""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.api import RoutingPolicy, register_policy
from repro.core.policies.cache_aware import WAIT_WEIGHT
from repro.core.policies.routing import least_loaded
from repro.core.prefix_tree import path_fingerprints, session_segments


@register_policy("cache_aware_gossip")
class CacheAwareGossipRouting(RoutingPolicy):
    """Route to the cheapest (digest-estimated prefill + queue wait)
    instance, reading gossiped cache digests instead of the caches.
    Sessionless requests fall back to least_loaded; a fleet with no
    gossip plane attached degrades to least_loaded-with-wait (every
    estimate is 0). Pooled-mode pinning mirrors ``cache_aware``."""

    needs_sessions = True

    def __init__(self, cfg):
        super().__init__(cfg)
        self._pinned: Dict[int, int] = {}           # rid -> pre-bound inst

    def _estimate(self, inst, req, router, eff: int) -> int:
        plane = router.gossip
        if plane is None:
            return 0
        digest = plane.get(inst.inst_id, router.clock)
        if digest is None:                           # unknown or too stale
            return 0
        segs = req.prefix_segments or session_segments(req.session_id, eff)
        want = path_fingerprints(segs)
        by_fp = dict(digest.entries)
        est = 0
        for fp, cum in want:                         # shallow -> deep
            adv = by_fp.get(fp)
            if adv is not None:
                est = max(est, min(adv, cum))
        est = min(est, eff - 1)
        cache = inst.prefix_cache
        floor = cache.cfg.min_hit_tokens if cache is not None else 0
        if est < floor:
            return 0
        return int(est * plane.discount(digest.age(router.clock)))

    def pick(self, cand, req, router):
        if req is None or req.session_id < 0:
            return least_loaded(cand)
        cm = router.prefill_cm
        eff = max(req.prompt_len - req.migrated_tokens, 1)
        per_queued = WAIT_WEIGHT * cm.prefill_latency(eff)

        def score(inst):
            est = self._estimate(inst, req, router, eff)
            remaining = cm.prefill_latency(max(eff - est, 1))
            return (remaining + inst.queue_depth * per_queued,
                    inst.load(), inst.inst_id)

        return min(cand, key=score)

    def pin_for_prefill(self, cand, req, router):
        if req.session_id < 0:
            return None
        inst = self.pick(cand, req, router)
        self._pinned[req.rid] = inst.inst_id
        return inst

    def claim_pin(self, req) -> Optional[int]:
        return self._pinned.pop(req.rid, None)
