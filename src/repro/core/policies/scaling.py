"""Built-in autoscaler control-loop policies.

Each class is one loop's pure decision function, lifted out of the
``Autoscaler`` (which keeps the mechanism: cooldown bookkeeping and the
decision log). Signals are passed as a plain dict so a custom loop can
carry extra inputs without changing the mechanism's signature:

  * ``decode_fleet``   — signals: snaps (List[InstanceSnapshot]),
    viol_frac, ft_backlog
  * ``pooled_prefill`` — signals: snap (PrefillPoolSnapshot), n_serving,
    ttft_slo_s
  * ``chunked_budget`` — signals: wait_p99, viol_frac, budget, lo, hi,
    n_serving, ttft_slo_s
"""

from __future__ import annotations

import math
from typing import Dict

from repro.core.api import ScalingPolicy, register_policy
from repro.core.autoscaler import ScaleDecision


def coordinated_prefill_floor(cfg, n_serving: int) -> int:
    """Coordinated pool floor: the prefill tier tracks the decode tier
    (``prefill_per_decode`` workers per serving instance) so a decode
    scale-up pulls prefill capacity with it instead of waiting for the
    queue to back up first."""
    floor = max(cfg.min_prefill,
                math.ceil(cfg.prefill_per_decode * n_serving))
    return min(floor, cfg.max_prefill)


@register_policy("decode_fleet")
class DecodeFleetScaling(ScalingPolicy):
    """The decode loop: grow/shrink the serving fleet, flip roles between
    decode-only / co-located / finetune-dedicated on QoS headroom and
    finetune backlog ("Taming the Chaos"-style small reversible steps)."""

    def decide(self, t: float, cfg, signals: Dict) -> ScaleDecision:
        snaps = signals["snaps"]
        viol_frac = signals["viol_frac"]
        ft_backlog = signals["ft_backlog"]
        serving = [s for s in snaps if s.role != "finetune"
                   and not s.draining]
        n_serving = len(serving)
        mean_load = (sum(s.load for s in serving) / n_serving) \
            if n_serving else 1.0
        colocated = [s for s in serving if s.role == "colocated"]
        paused = [s for s in serving if s.role == "decode" and s.colocatable]
        dedicated = [s for s in snaps if s.role == "finetune"
                     and s.colocatable and s.can_serve and not s.draining]

        # --- QoS pressure: shed finetune first, then grow the fleet ------
        if viol_frac > cfg.viol_frac_shed:
            if colocated:
                victim = max(colocated, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_decode", victim.inst_id,
                                     f"viol={viol_frac:.3f}")
            if n_serving < cfg.max_decode:
                return ScaleDecision(t, "add_instance",
                                     reason=f"viol={viol_frac:.3f}")
            return ScaleDecision(t, "none", reason="at max_decode")
        if mean_load > cfg.scale_up_load:
            if n_serving < cfg.max_decode:
                return ScaleDecision(t, "add_instance",
                                     reason=f"load={mean_load:.2f}")
            if colocated:
                victim = max(colocated, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_decode", victim.inst_id,
                                     f"load={mean_load:.2f} at max_decode")
            return ScaleDecision(t, "none", reason="at max_decode")

        # --- headroom: give capacity back to finetune --------------------
        if viol_frac < cfg.viol_frac_resume and ft_backlog > 0:
            if paused:
                pick = min(paused, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_colocated", pick.inst_id,
                                     f"backlog={ft_backlog:.1f}")
            idle = [s for s in colocated
                    if s.load <= cfg.idle_load_ft and s.active == 0]
            if idle and n_serving > cfg.min_decode:
                pick = min(idle, key=lambda s: (s.load, s.inst_id))
                return ScaleDecision(t, "to_finetune", pick.inst_id,
                                     f"backlog={ft_backlog:.1f} idle fleet")

        # --- sustained low load: shrink ----------------------------------
        if mean_load < cfg.scale_down_load and n_serving > cfg.min_decode:
            pick = min(serving, key=lambda s: (s.load, s.inst_id))
            return ScaleDecision(t, "remove_instance", pick.inst_id,
                                 f"load={mean_load:.2f}")
        # finetune-dedicated instances rejoin serving when load recovers
        if dedicated and mean_load > 2 * cfg.scale_down_load:
            pick = min(dedicated, key=lambda s: s.inst_id)
            return ScaleDecision(t, "to_colocated", pick.inst_id,
                                 "load recovered")
        return ScaleDecision(t, "none")


@register_policy("pooled_prefill")
class PooledPrefillScaling(ScalingPolicy):
    """The prefill-pool loop: grow on TTFT-headroom loss or queue depth,
    shrink on deep idle, never below the floor coordinated with the
    decode fleet."""

    def decide(self, t: float, cfg, signals: Dict) -> ScaleDecision:
        snap = signals["snap"]
        n_serving = signals["n_serving"]
        slo = signals["ttft_slo_s"]
        n = snap.n_workers
        floor = coordinated_prefill_floor(cfg, n_serving)
        if n < floor:
            return ScaleDecision(t, "add_prefill",
                                 reason=f"floor={floor} serving={n_serving}")
        # TTFT headroom / queue pressure -> grow
        if n < cfg.max_prefill:
            if snap.queue_depth > cfg.prefill_queue_hi * max(n, 1):
                return ScaleDecision(t, "add_prefill",
                                     reason=f"queue={snap.queue_depth}")
            if slo > 0 and snap.wait_p99 > cfg.ttft_headroom * slo:
                return ScaleDecision(
                    t, "add_prefill",
                    reason=f"wait_p99={snap.wait_p99:.2f}")
        # deep idle above the coordinated floor -> shrink
        if n > floor and snap.queue_depth == 0 \
                and snap.backlog_s <= cfg.prefill_idle_backlog_s \
                and (slo <= 0 or snap.wait_p99 <
                     0.5 * cfg.ttft_headroom * slo):
            return ScaleDecision(t, "remove_prefill",
                                 reason=f"idle backlog={snap.backlog_s:.2f}")
        return ScaleDecision(t, "none")


@register_policy("chunked_budget")
class ChunkedBudgetScaling(ScalingPolicy):
    """The chunked-mode prefill loop: AIMD-tune the fleet-wide per-round
    chunk budget against TTFT headroom, escalating to fleet growth once
    the budget is maxed (in chunked mode prefill capacity IS the decode
    fleet)."""

    def decide(self, t: float, cfg, signals: Dict) -> ScaleDecision:
        wait_p99 = signals["wait_p99"]
        viol_frac = signals["viol_frac"]
        budget = signals["budget"]
        lo, hi = signals["lo"], signals["hi"]
        n_serving = signals["n_serving"]
        slo = signals["ttft_slo_s"]
        step = cfg.chunk_step_tokens
        # TTFT headroom eroding -> spend more of each round on prefill;
        # once the budget is maxed (or the QoS price caps below it), the
        # only remaining lever is decode capacity itself — in chunked mode
        # prefill capacity IS the decode fleet, so this loop may grow it
        if slo > 0 and wait_p99 > cfg.ttft_headroom * slo:
            if budget < hi:
                # multiplicative increase / additive decrease: a backlog
                # compounds while the budget crawls, so growth must outrun
                # it — escalation to fleet growth then starts within a few
                # ticks instead of after max_budget/step of them
                return ScaleDecision(
                    t, "grow_chunk_budget", target=min(budget * 2, hi),
                    reason=f"chunk_wait_p99={wait_p99:.2f}")
            if n_serving < cfg.max_decode:
                return ScaleDecision(
                    t, "add_instance",
                    reason=f"chunk_wait_p99={wait_p99:.2f} budget maxed")
            return ScaleDecision(t, "none", reason="at max_decode")
        # TTFT comfortable but TPOT under pressure -> hand tokens back
        if budget > lo and viol_frac > cfg.viol_frac_shed and \
                (slo <= 0 or wait_p99 < 0.5 * cfg.ttft_headroom * slo):
            return ScaleDecision(
                t, "shrink_chunk_budget", target=max(budget - step, lo),
                reason=f"viol={viol_frac:.3f}")
        return ScaleDecision(t, "none")
