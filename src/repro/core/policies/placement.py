"""Built-in prefill placements — the three deployment modes
(docs/cluster.md "Three deployment modes") as self-contained policy
objects.

Each placement owns everything mode-specific that used to be spread over
``router.py`` and ``cluster.py`` branches: the per-instance chain clocks
(``chained``), the shared ``PrefillPool`` + its peak/timeline accounting
(``pooled``), and the fleet-wide chunk budget + its control trajectory
(``chunked``). ``ClusterRouter`` and ``ClusterSim`` call the placement
through the ``PrefillPlacement`` interface (core/api.py) and never
branch on the mode string again.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.api import PENDING, PrefillPlacement, register_policy
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.prefill_pool import PrefillPool, PrefillPoolConfig
from repro.core.simulator import ChunkedPrefillConfig


@register_policy("chained")
class ChainedPlacement(PrefillPlacement):
    """PR 1's measurable baseline: prefill serialized on a per-instance
    partner chain; the chosen decode instance's chain runs the prefill,
    then decode admission takes over."""

    def __init__(self):
        self._free: Dict[int, float] = {}           # inst id -> chain clock

    def on_add_instance(self, inst, now, router) -> None:
        self._free[inst.inst_id] = now

    def on_retire_instance(self, inst_id, router) -> None:
        self._free.pop(inst_id, None)

    def place(self, req, now, cand, router) -> int:
        # a partially-migrated request must land where its KV tail lives;
        # the shortened effective_prompt_len prices the tail re-prefill
        inst = router.claim_forced(req)
        if inst is None:
            inst = router.pick_decode(cand, req)
            router.credit_prefix(inst, req)
        t_start = max(self._free.get(inst.inst_id, now), req.arrival, now)
        ready = t_start + router.prefill_cm.prefill_latency(
            req.effective_prompt_len)
        self._free[inst.inst_id] = ready
        req.prefill_done = ready
        inst.enqueue(req, ready)
        return inst.inst_id


@register_policy("pooled")
class PooledPlacement(PrefillPlacement):
    """Disaggregated prefill tier (core/prefill_pool.py): admission
    submits into the shared EDF queue, ``pump`` hands completed prefills
    to the decode stage, and the cluster-side hooks size the pool with
    the ``pooled_prefill`` scaling policy, keep its floor coordinated
    with decode scale-ups, and account its timeline/peaks."""

    def __init__(self, pool: PrefillPool):
        self.pool = pool
        self._peak = len(pool.workers)
        self._timeline: List[Tuple[float, int, int]] = []

    @classmethod
    def build(cls, cs) -> "PooledPlacement":
        pool = PrefillPool(
            cs.cluster.prefill or PrefillPoolConfig(),
            CostModel(cs.cfg_inf, InstanceSpec(tp=cs.sim.tp),
                      seed=cs.sim.seed + 7),
            ttft_slo_s=cs.router_cfg.ttft_slo_s)
        return cls(pool)

    # ---- router side ----
    def saturated(self, cand, router) -> bool:
        # prefill-tier backpressure: in pool mode decode load() only rises
        # after prefill, so saturation must also be read off the pool
        # queue — the same per-instance bound reject_load puts on a decode
        # queue, summed fleet-wide (summing keeps the limit correct when
        # instance_overrides make slot budgets heterogeneous; identical to
        # max_slots * n_serving on a uniform fleet)
        limit = router.cfg.reject_load \
            * sum(i.sim.max_slots for i in router.serving_instances())
        return self.pool.queue_depth >= limit

    def place(self, req, now, cand, router) -> int:
        # the cache can only shorten prefill if the decode target is known
        # BEFORE the pool runs it: a pinning policy (session_affinity,
        # cache_aware) binds the instance now and the pin is honored at
        # hand-off; non-pinning policies choose at hand-off time
        # a partially-migrated request is already bound to the instance
        # holding its KV tail (router._forced, honored at hand-off) — an
        # admission pin would fight the forced destination
        if not router.has_forced(req.rid):
            pin = router.policy.pin_for_prefill(cand, req, router)
            if pin is not None:
                router.credit_prefix(pin, req)
        self.pool.submit(req, now)
        return PENDING

    def pump(self, until, router) -> int:
        handed = 0
        for req, ready in self.pool.pump(until):
            router.dispatch_decode(req, ready)
            handed += 1
        return handed

    # ---- cluster side ----
    def on_scale_up(self, cs, t) -> None:
        # coordinated scaling: a decode scale-up pulls the prefill pool
        # to its floor immediately (the legacy chain got this for free —
        # every instance carried a chain), instead of waiting a tick
        from repro.core.autoscaler import ScaleDecision
        floor = cs.autoscaler.prefill_floor(
            len(cs.router.serving_instances()))
        while len(self.pool.active_workers()) < floor:
            self.pool.add_worker(t)
            cs.autoscaler.decisions.append(ScaleDecision(
                t, "add_prefill", reason="coordinated scale-up"))
        self._peak = max(self._peak, len(self.pool.active_workers()))

    def control(self, cs, t, viol_frac) -> None:
        d = cs.autoscaler.evaluate_prefill(
            t, self.pool.snapshot(t),
            n_serving=len(cs.router.serving_instances()))
        if d.action == "add_prefill":
            self.pool.add_worker(t)
            self._peak = max(self._peak, len(self.pool.active_workers()))
        elif d.action == "remove_prefill":
            # guard at application time: never drain below the hard floor
            self.pool.drain_worker(
                min_workers=max(cs.cluster.autoscaler.min_prefill, 1))

    def retire(self, cs, t) -> None:
        self.pool.retire_drained(t)

    def record_timeline(self, cs, t) -> None:
        n_active = len(self.pool.active_workers())
        self._timeline.append((t, n_active, self.pool.queue_depth))
        self._peak = max(self._peak, n_active)

    def finalize(self, cs, res) -> None:
        res.prefill_timeline = self._timeline
        res.final_prefill = len(self.pool.active_workers())
        res.peak_prefill = max(self._peak, res.final_prefill)


@register_policy("chunked")
class ChunkedPlacement(PrefillPlacement):
    """No prefill tier at all: the request is placed on a decode instance
    at admission and that instance runs its prefill in chunks mixed into
    decode rounds (``DecodeInstanceSim.enqueue_chunked``) under a
    QoS-priced per-round token budget. The placement owns the fleet-wide
    budget: the ``chunked_budget`` scaling policy tunes it, spawns
    inherit the current value, and its trajectory lands in
    ``ClusterResult.chunk_budget_timeline``."""

    def __init__(self, cfg: ChunkedPrefillConfig = None):
        self.cfg = cfg or ChunkedPrefillConfig()
        # the initial budget must already sit inside the control loop's
        # operating range, or the AIMD tuner starts out of bounds
        self.budget = int(min(max(self.cfg.budget_tokens,
                                  self.cfg.min_budget), self.cfg.max_budget))
        self._timeline: List[Tuple[float, int]] = []

    @classmethod
    def build(cls, cs) -> "ChunkedPlacement":
        return cls(cs.cluster.chunked)

    # ---- router side ----
    def place(self, req, now, cand, router) -> int:
        # the instance itself chunks the prefill into its decode rounds;
        # load()/queue_depth include the chunk queue so admission
        # backpressure keeps working
        inst = router.claim_forced(req)
        if inst is None:
            inst = router.pick_decode(cand, req)
            router.credit_prefix(inst, req)
        inst.enqueue_chunked(req, now)
        return inst.inst_id

    # ---- cluster side ----
    def spawn_kwargs(self, cs, serves_inference) -> Dict:
        if not serves_inference:
            return {}
        # a late joiner starts at the fleet's CURRENT budget, not t=0's
        return {"chunked": dataclasses.replace(
            self.cfg, budget_tokens=self.budget)}

    def control(self, cs, t, viol_frac) -> None:
        # mode-aware prefill loop: no pool to size — tune the per-round
        # chunk budget against TTFT headroom, and escalate to fleet
        # growth once the budget is maxed
        d = cs.autoscaler.evaluate_chunked(
            t, cs.router.recent_chunk_wait_p99(t), viol_frac,
            self.budget, self.cfg.min_budget, self.cfg.max_budget,
            n_serving=len(cs.router.serving_instances()))
        if d.action == "add_instance":
            cs.apply_decision(d, t)
        elif d.action in ("grow_chunk_budget", "shrink_chunk_budget"):
            # fleet-wide budget change (the decision's target carries the
            # new budget); future spawns inherit it via spawn_kwargs
            self.budget = int(min(max(d.target, self.cfg.min_budget),
                                  self.cfg.max_budget))
            for inst in cs.router.instances.values():
                if inst.chunked is not None:
                    inst.chunk_budget = self.budget

    def record_timeline(self, cs, t) -> None:
        self._timeline.append((t, self.budget))

    def finalize(self, cs, res) -> None:
        res.chunk_budget_timeline = self._timeline
        res.final_chunk_budget = self.budget
