"""``cache_aware`` routing: cross-instance prefix-cache-aware placement.

The ROADMAP open item this closes: ``session_affinity`` is *sticky* — it
remembers where a session was sent, not where its KV actually lives, and
its only load control is a hard overflow cliff (past
``affinity_overflow_load`` the session remaps to the least-loaded
instance and the warm cache is abandoned for good). The two notions
diverge exactly when placement matters most: after an overflow remap,
after a drain/retire or role flip invalidates the sticky entry, and
whenever several instances hold partial prefixes of different lengths.

This policy consults the caches themselves: for each candidate it peeks
the instance's ``PrefixCache`` (core/prefix_cache.py, non-mutating
``peek``) and scores the placement by *estimated time-to-first-token
work* —

    score(inst) = prefill_cost(prompt - cached_prefix)
                + WAIT_WEIGHT * queue_depth * prefill_cost(prompt)

the first term is the prefill this instance still has to run (a longer
matching prefix makes it cheaper), the second a wait proxy charging each
queued request ahead a small fraction of one prompt's prefill (decode
rounds batch and prefill tiers pipeline, so a queued request delays a
newcomer far less than a serialized prefill would — WAIT_WEIGHT=0.05
calibrated on the session_heavy scenario across all three modes).
Minimizing the sum trades cache benefit against load continuously
instead of cliff-switching, so a warm instance with a small queue beats
a cold idle one only while the saved prefill outweighs the wait — and a
session that detoured during a burst *returns* to its warm cache when
the queue drains, which the sticky map cannot do.

This module is also the worked proof that the control-plane API
(core/api.py) is real: it is registered purely through the public
``@register_policy`` decorator — ``ClusterRouter``'s dispatch path has no
``cache_aware`` branch anywhere — and every entry point
(``ExperimentSpec``, ``examples/cluster_sim.py --policy cache_aware``,
the ``cluster_cache_aware`` benchmark) picks it up by name. docs/api.md
walks through it line by line as the "write your own policy" example.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.api import RoutingPolicy, register_policy
from repro.core.policies.routing import least_loaded

# wait-proxy weight: fraction of one full-prompt prefill charged per
# queued request ahead (see module docstring)
WAIT_WEIGHT = 0.05


@register_policy("cache_aware")
class CacheAwareRouting(RoutingPolicy):
    """Route to the cheapest (cache-credited prefill + queue wait)
    instance, reading every candidate's ``PrefixCache`` instead of a
    sticky map. Sessionless requests fall back to least_loaded. Pooled-
    mode pinning mirrors ``session_affinity``: the chosen instance is
    bound at admission (before prefill runs) so the cache credit can
    shorten the prefill, and honored at hand-off."""

    needs_sessions = True

    def __init__(self, cfg):
        super().__init__(cfg)
        self._pinned: Dict[int, int] = {}           # rid -> pre-bound inst

    def pick(self, cand, req, router):
        if req is None or req.session_id < 0:
            return least_loaded(cand)
        cm = router.prefill_cm
        # effective_prompt_len semantics: migrated KV needs no prefill
        # anywhere and the cache lookup at dispatch is bounded the same
        # way (router.credit_prefix), so a cross-session tree hit is
        # never double-credited on top of a migration credit
        eff = max(req.prompt_len - req.migrated_tokens, 1)
        per_queued = WAIT_WEIGHT * cm.prefill_latency(eff)

        def score(inst):
            hit = 0
            if inst.prefix_cache is not None:
                router.dispatch_peeks += 1
                hit = inst.prefix_cache.peek(req.session_id, eff,
                                             segments=req.prefix_segments)
            remaining = cm.prefill_latency(max(eff - hit, 1))
            # ties (e.g. nothing cached anywhere) break like least_loaded
            return (remaining + inst.queue_depth * per_queued,
                    inst.load(), inst.inst_id)

        return min(cand, key=score)

    def pin_for_prefill(self, cand, req, router):
        if req.session_id < 0:
            return None
        inst = self.pick(cand, req, router)
        self._pinned[req.rid] = inst.inst_id
        return inst

    def claim_pin(self, req) -> Optional[int]:
        return self._pinned.pop(req.rid, None)
