"""Built-in decode-routing policies (see core/router.py module docs for
the semantics each one guarantees).

Every policy owns its decision state — the RNG (``random``), the cursor
(``round_robin``), the sticky-session map and pooled-mode admission pins
(``session_affinity``) — so ``ClusterRouter`` holds none of it and a new
policy is a registered class, not a branch in the dispatch path.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.api import RoutingPolicy, register_policy


def least_loaded(cand: List):
    """Join-shortest-queue on the occupancy signal; ties broken by
    instance id for determinism. Shared fallback for every policy."""
    return min(cand, key=lambda i: (i.load(), i.inst_id))


@register_policy("least_loaded")
class LeastLoadedRouting(RoutingPolicy):
    def pick(self, cand, req, router):
        return least_loaded(cand)


@register_policy("round_robin")
class RoundRobinRouting(RoutingPolicy):
    def __init__(self, cfg):
        super().__init__(cfg)
        self._cursor = 0

    def pick(self, cand, req, router):
        pick = cand[self._cursor % len(cand)]
        self._cursor += 1
        return pick


@register_policy("random")
class RandomRouting(RoutingPolicy):
    def __init__(self, cfg):
        super().__init__(cfg)
        # cfg.seed None means "unseeded config" (the cluster layer derives
        # one before building the router); a bare ClusterRouter must still
        # be deterministic, so fall back to 0 rather than OS entropy
        self._rng = np.random.default_rng(
            0 if cfg.seed is None else cfg.seed)

    def pick(self, cand, req, router):
        return cand[int(self._rng.integers(len(cand)))]


@register_policy("predicted_latency")
class PredictedLatencyRouting(RoutingPolicy):
    """Lowest *predicted TPOT* from the fitted TwoStageLatencyPredictor
    at the instance's current batch and finetune quantum, plus a
    slot-overflow wait term; least_loaded fallback when no predictor is
    fitted (e.g. separate mode)."""

    def pick(self, cand, req, router):
        if router.predictor is None or req is None:
            return least_loaded(cand)
        return min(cand, key=lambda i: (self._delay(i, req, router),
                                        i.inst_id))

    @staticmethod
    def _tpot(inst, req, router) -> float:
        """Predicted decode-round latency (== TPOT) on `inst` with `req`
        added, at the instance's current batch and finetune quantum."""
        bs = min(inst.queue_depth + 1, inst.sim.max_slots)
        if inst.active:
            ctx = sum(r.context_len for r in inst.active) / len(inst.active)
        else:
            ctx = float(req.prompt_len)
        q_ft = 0.0
        if inst.role == "colocated" and inst.quantum_timeline:
            q_ft = inst.quantum_timeline[-1][1] / max(inst.sim.k_max, 1)
        return router.predictor.predict_colo(q_ft, bs, ctx)

    def _delay(self, inst, req, router) -> float:
        """Routing score: predicted TPOT, plus the admission wait the
        request would pay when the instance's queue spills past its slot
        budget. Decode is memory-bound, so TPOT alone is nearly flat in
        batch size — without the wait term a saturated instance looks as
        cheap as an idle one and the policy piles onto it."""
        tpot = self._tpot(inst, req, router)
        slots = max(inst.sim.max_slots, 1)
        excess = inst.queue_depth + 1 - slots
        if excess <= 0:
            return tpot
        # each slot-budget overflow "wave" waits a full request residency
        # (remaining tokens at this round's predicted TPOT)
        rem = [r.max_new_tokens - r.generated for r in inst.active]
        mean_rem = (sum(rem) / len(rem)) if rem else req.max_new_tokens
        waves = math.ceil(excess / slots)
        return tpot * (1.0 + waves * max(mean_rem, 1.0))


@register_policy("session_affinity")
class SessionAffinityRouting(RoutingPolicy):
    """``Request.session_id`` maps to a sticky instance for prefix-cache
    reuse, overflowing (and remapping) to the least-loaded instance when
    the sticky one is past ``affinity_overflow_load``. In pooled mode
    the sticky instance is pinned at admission so its cache credit can
    shorten the prefill, and the pin is honored at hand-off."""

    needs_sessions = True

    def __init__(self, cfg):
        super().__init__(cfg)
        self._session_map: Dict[int, int] = {}      # session -> sticky inst
        self._pinned: Dict[int, int] = {}           # rid -> pre-bound inst

    def pick(self, cand, req, router):
        if req is not None and req.session_id >= 0:
            sticky = self._session_map.get(req.session_id)
            if sticky is not None:
                inst = router.instances.get(sticky)
                if inst is not None and inst in cand and \
                        inst.load() <= self.cfg.affinity_overflow_load:
                    return inst
            # first touch, sticky gone, or overflow: remap the session to
            # the least-loaded instance (the prefix cache moves with it)
            pick = least_loaded(cand)
            self._session_map[req.session_id] = pick.inst_id
            return pick
        return least_loaded(cand)

    def pin_for_prefill(self, cand, req, router):
        if req.session_id < 0:
            return None
        inst = self.pick(cand, req, router)
        self._pinned[req.rid] = inst.inst_id
        return inst

    def claim_pin(self, req) -> Optional[int]:
        return self._pinned.pop(req.rid, None)
