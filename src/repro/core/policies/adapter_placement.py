"""Built-in adapter_placement policies (multi-LoRA serving).

The decision these policies own: for a request carrying an adapter_id,
trade adapter *locality* (an instance already holding the stamped
version serves it with zero hot-load/swap cost) against *load balance*
(packing a hot tenant onto one instance starves its queue). Requests
without an adapter — and every request when ``ClusterConfig.adapters``
is None — never reach these policies; they go through the ``routing``
policy unchanged.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core.api import AdapterPlacement, register_policy
from repro.core.policies.routing import least_loaded


def _holders(cand: List, req) -> List:
    """Instances whose adapter pool already holds the exact
    (adapter_id, version) the request was stamped with."""
    return [i for i in cand
            if getattr(i, "adapters", None) is not None
            and i.adapters.has(req.adapter_id, req.adapter_version)]


@register_policy("affinity_packed")
class AffinityPackedPlacement(AdapterPlacement):
    """Pack each adapter onto as few instances as possible: prefer the
    least-loaded instance already holding the stamped version, spilling
    to the fleet-wide least-loaded instance only when every holder is
    past ``RouterConfig.affinity_overflow_load`` (the same overflow knob
    session_affinity uses). Minimizes swaps; a hot tenant grows replicas
    only under real load pressure."""

    def pick(self, cand, req, router):
        holders = _holders(cand, req)
        if holders:
            best = least_loaded(holders)
            if best.load() <= self.cfg.affinity_overflow_load:
                return best
        return least_loaded(cand)


@register_policy("replicate_hot")
class ReplicateHotPlacement(AdapterPlacement):
    """Deliberately replicate hot adapters: a tenant whose running share
    of adapter traffic reaches its fair share (1/n_candidates) is routed
    pure least-loaded — its adapter spreads across the fleet, buying
    balance at the cost of extra hot-loads — while cold tenants stay
    packed on their holders like affinity_packed."""

    def __init__(self, cfg):
        super().__init__(cfg)
        self._counts: Dict[int, int] = {}
        self._total = 0

    def pick(self, cand, req, router):
        n = self._counts.get(req.adapter_id, 0) + 1
        self._counts[req.adapter_id] = n
        self._total += 1
        if n / self._total >= 1.0 / max(len(cand), 1):
            return least_loaded(cand)
        holders = _holders(cand, req)
        if holders:
            best = least_loaded(holders)
            if best.load() <= self.cfg.affinity_overflow_load:
                return best
        return least_loaded(cand)
