"""Built-in control-plane policies (core/api.py registry).

Importing this package registers every built-in policy:

  * routing    — least_loaded / round_robin / random / predicted_latency /
                 session_affinity (core/policies/routing.py)
  * routing    — cache_aware, the registry's proof-of-API plugin
                 (core/policies/cache_aware.py, docs/api.md walkthrough)
  * routing    — cache_aware_gossip, the fleet-scale variant scoring
                 gossiped cache digests with zero synchronous peeks
                 (core/policies/cache_aware_gossip.py, core/gossip.py)
  * prefill    — chained / pooled / chunked deployment modes
                 (core/policies/placement.py)
  * scaling    — decode_fleet / pooled_prefill / chunked_budget autoscaler
                 loops (core/policies/scaling.py)
  * migration  — kv_headroom / least_loaded live-KV-migration destination
                 choices (core/policies/migration.py)
  * adapter_placement — affinity_packed / replicate_hot multi-LoRA
                 serving placements (core/policies/adapter_placement.py)

The registry imports this package lazily on first resolve, so user code
never needs to import it explicitly; third-party policies just call
``repro.core.api.register_policy`` from their own module.
"""

from repro.core.policies import adapter_placement  # noqa: F401
from repro.core.policies import cache_aware  # noqa: F401
from repro.core.policies import cache_aware_gossip  # noqa: F401
from repro.core.policies import migration  # noqa: F401
from repro.core.policies import placement  # noqa: F401
from repro.core.policies import routing  # noqa: F401
from repro.core.policies import scaling  # noqa: F401
