"""Cluster simulation: two-tier routing plane + autoscaler over stepped
decode instances.

Composes the pieces into one discrete-event experiment:

  * a trace of requests arrives at the cluster front door;
  * ClusterRouter (core/router.py) admits each request (or rejects it
    under saturation) into the configured prefill placement;
  * completed prefills are handed to one decode instance chosen by the
    registered routing policy (core/policies/routing.py + plugins);
  * every DecodeInstanceSim advances on a shared clock via its step() API;
  * the Autoscaler (core/autoscaler.py) runs two coordinated control loops
    every interval: the decode loop grows/shrinks the fleet or flips roles
    between decode-only, co-located and finetune-dedicated; the prefill
    loop is owned by the placement (pool sizing in pooled mode, chunk-
    budget tuning in chunked mode, idle in chained mode).

This module is **mechanism**: the shared clock, arrival dispatch, epoch
stepping, drain/retire lifecycle, decision application and result
accounting. Everything mode-specific lives in the ``PrefillPlacement``
policy object (core/policies/placement.py) resolved by name from
``ClusterConfig.prefill_mode`` — ``chained`` is PR 1's per-instance
serialized prefill chain (the measurable baseline, also selected by
``prefill=None``); ``pooled`` is the disaggregated pool; ``chunked``
mixes prefill chunks into the decode instances' own rounds (docs/
cluster.md). ``ClusterConfig.prefix_cache`` additionally gives every
serving instance a session prefix cache (core/prefix_cache.py) so
cache-aware routing shortens effective prefill on hits.

``ClusterConfig.instance_overrides`` is the heterogeneous-fleet hook:
entry *i* overrides ``SimConfig`` fields (tp, max_slots, qos_s, ...) for
the *i*-th spawned instance, so a fleet can mix hardware shapes in one
experiment (``ExperimentSpec`` validates the keys).

Modes mirror the single-instance experiment (paper §8.1) at fleet scale:
  harli    — every serving instance co-locates a finetune job, dynamic
             quantum, roles under autoscaler control
  separate — serving instances are decode-only; one dedicated finetune
             instance free-runs (same total fleet size as harli, except
             n_initial=1 where separate floors at 1 decode + 1 finetune
             instance — MORE hardware than harli's single instance, so
             the comparison is conservative against harli there)
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import tempfile
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import api
from repro.core.adapters import (AdapterRegistry, AdapterServingConfig,
                                 InstanceAdapterConfig, adapter_bytes)
from repro.core.allocator import BLOCK_BYTES
from repro.core.autoscaler import (Autoscaler, AutoscalerConfig,
                                   InstanceSnapshot, ScaleDecision)
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.gossip import GossipConfig, GossipPlane
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.prefix_cache import PrefixCacheConfig
from repro.core.router import ClusterRouter, ClusterStats, RouterConfig
from repro.core.simulator import (ChunkedPrefillConfig, DecodeInstanceSim,
                                  FinetuneCheckpointer, SimConfig,
                                  fit_predictor)
from repro.models.config import ModelConfig
from repro.serving.request import Request
from repro.serving.trace import FailureConfig, FailureSchedule

ROUTER_SEED_SALT = 17        # RouterConfig.seed derives from SimConfig.seed
SHED_SEED_SALT = 53987       # backoff-jitter stream (DegradationConfig.seed)


@dataclasses.dataclass(frozen=True)
class KVMigrationConfig:
    """Live KV migration on a preemption warning (survivability layer).

    When an instance receives a spot-style notice (``FailureConfig.
    warning_s > 0``), the cluster streams each victim request's KV to a
    peer over the interconnect, racing the deadline kill: transfers that
    finish in time land the request on the destination with its context
    intact; losers fall back to the PR 6 re-prefill path — partially,
    when some prefix tokens made it across before the kill."""

    # victim egress interconnect bandwidth (the serialized link every
    # transfer shares). Typical datacenter ICI/NVLink-over-fabric ballpark
    bw_gbps: float = 8.0
    setup_s: float = 0.005           # per-request transfer handshake
    # registered migration destination policy (core/policies/migration.py)
    policy: str = "kv_headroom"

    @property
    def bw_bytes(self) -> float:
        return self.bw_gbps * 1e9


@dataclasses.dataclass(frozen=True)
class DegradationConfig:
    """Overload degradation ladder, escalated one deterministic step per
    epoch off the fleet's recent request-level SLO-violation fraction
    (``ClusterRouter.recent_slo_violation_frac``):

      level 1 — fleet-wide finetune circuit breaker: every colocated
                quantum yields to inference (``DecodeInstanceSim.
                ft_breaker``) until the violation fraction recovers;
      level 2 — admission-control load shedding: arrivals re-enter after
                a seeded jittered exponential backoff (priced into TTFT
                via ``Request.retries``), hard-rejected past the cap.

    De-escalation steps down one level per epoch once the violation
    fraction drops under ``resume_viol_frac``. Thresholds are calibrated
    against the request-level signal: a healthy loaded fleet sits well
    above zero (churn requeues and TTFT tails count), so the breaker
    arms at a clear excursion and shedding only at near-collapse."""

    breaker_viol_frac: float = 0.35  # escalate 0 -> 1 at this violation frac
    shed_viol_frac: float = 0.70     # escalate 1 -> 2
    resume_viol_frac: float = 0.15   # de-escalate one level below this
    shed: bool = True                # enable level 2 at all
    backoff_base_s: float = 1.0      # first retry delay
    backoff_mult: float = 2.0        # exponential growth per retry
    backoff_jitter: float = 0.25     # uniform +/- fraction, own RNG stream
    max_retries: int = 3             # then hard rejection (shed_rejected)
    # None = derive from the experiment seed (SimConfig.seed + SHED_SEED_
    # SALT); any int — including 0 — is explicit and honored as-is
    seed: Optional[int] = None


@dataclasses.dataclass
class ClusterConfig:
    n_initial: int = 2               # serving fleet size at t=0
    tick_s: float = 1.0              # event-loop / dispatch epoch
    autoscale: bool = True
    router: RouterConfig = dataclasses.field(default_factory=RouterConfig)
    autoscaler: AutoscalerConfig = dataclasses.field(
        default_factory=AutoscalerConfig)
    # deployment mode: any registered prefill placement ("chained" |
    # "pooled" | "chunked" built in). None (default) derives it from
    # `prefill` for backward compatibility: a pool config means "pooled",
    # prefill=None means the PR 1 chain ("chained")
    prefill_mode: Optional[str] = None
    # prefill tier: None = legacy per-instance prefill chain (PR 1)
    prefill: Optional[PrefillPoolConfig] = dataclasses.field(
        default_factory=PrefillPoolConfig)
    # chunked-mode knobs (per-round token budget + autoscaler range)
    chunked: ChunkedPrefillConfig = dataclasses.field(
        default_factory=ChunkedPrefillConfig)
    # per-instance session prefix cache; None = cache-less (PR 3 behaviour)
    prefix_cache: Optional[PrefixCacheConfig] = None
    # asynchronous cache-summary gossip plane (core/gossip.py): each
    # instance's prefix tree publishes staleness-bounded digests the
    # cache_aware_gossip policy routes on. None (default) = no plane,
    # bit-identical to the gossip-less behaviour
    gossip: Optional[GossipConfig] = None
    # heterogeneous-fleet hook: entry i replaces SimConfig fields for the
    # i-th spawned instance (by spawn order; autoscaler spawns past the
    # list use the base SimConfig). Keys are validated by ExperimentSpec.
    instance_overrides: Tuple[Dict, ...] = ()
    # failure/preemption injection (serving/trace.py): seeded Poisson
    # instance kills, optional spot-style warnings, finetune checkpoint
    # cadence. None (default) = stable fleet, bit-identical to the
    # pre-failure-layer behaviour
    failures: Optional[FailureConfig] = None
    # live KV migration on preemption warnings; None (default) = warned
    # instances drain in place and their remnants re-prefill (PR 6 path)
    migration: Optional[KVMigrationConfig] = None
    # overload degradation ladder (finetune breaker -> load shedding ->
    # hard rejection); None (default) = no ladder, PR 6 behaviour
    degradation: Optional[DegradationConfig] = None
    # multi-LoRA adapter serving (core/adapters.py): colocated finetune
    # jobs publish versioned adapters the fleet hot-loads on demand.
    # None (default) = off, bit-identical to the adapter-less sim
    adapters: Optional[AdapterServingConfig] = None

    def resolved_mode(self) -> str:
        mode = self.prefill_mode
        if mode is None:
            mode = "pooled" if self.prefill is not None else "chained"
        api.resolve_policy("prefill", mode)    # raises on unknown names
        return mode


@dataclasses.dataclass
class ClusterResult:
    mode: str
    stats: ClusterStats
    ft_iterations: float = 0.0
    ft_throughput: float = 0.0       # iterations/s x minibatch (paper §8.2)
    ft_stall_rounds: int = 0
    qos_violation_frac: float = 0.0  # across all decode TPOT samples
    tpot: List[float] = dataclasses.field(default_factory=list)
    fleet_timeline: List[Tuple[float, int, int]] = dataclasses.field(
        default_factory=list)        # (t, serving, colocated)
    prefill_timeline: List[Tuple[float, int, int]] = dataclasses.field(
        default_factory=list)        # (t, active workers, queue depth)
    decisions: List[ScaleDecision] = dataclasses.field(default_factory=list)
    # hardware counts: ALL live instances, including separate mode's
    # dedicated finetune one — comparable across modes
    final_fleet: int = 0
    peak_fleet: int = 0
    final_prefill: int = 0
    peak_prefill: int = 0
    # chunked mode: the per-round budget's control trajectory
    chunk_budget_timeline: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)
    final_chunk_budget: int = 0
    # session prefix cache, aggregated over the fleet
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_hit_tokens: int = 0
    # cross-session sharing + gossip plane (ClusterConfig.gossip)
    prefix_shared_hit_tokens: int = 0  # hit tokens from cross-session reuse
    dispatch_peeks: int = 0          # synchronous cache probes at dispatch
    gossip_published: int = 0        # digests published fleet-wide
    gossip_bytes: int = 0            # total digest wire bytes
    gossip_stale_discards: int = 0   # reads refused past the staleness bound
    gossip_max_used_age: float = 0.0  # oldest digest age actually acted on
    # failure layer (ClusterConfig.failures)
    failures: int = 0                # hard kills applied (instances+workers)
    preemptions: int = 0             # graceful-drain warnings issued
    requeued_requests: int = 0       # in-flight requests re-routed off kills
    requeue_rejected: int = 0        # lost requests no survivor could absorb
    ft_lost_iterations: float = 0.0  # finetune progress rolled back by kills
    checkpoint_commits: int = 0
    # survivability layer (ClusterConfig.migration / .degradation)
    migrated_requests: int = 0       # KV transfers that beat the deadline
    migration_reprefills: int = 0    # warned-instance remnants requeued
    migrated_kv_tokens: int = 0      # KV tokens shipped (full + partial)
    shed_requests: int = 0           # ladder level-2 backoff events
    shed_rejected: int = 0           # shed past max_retries (hard rejects)
    breaker_epochs: int = 0          # epochs at ladder level >= 1
    shed_epochs: int = 0             # epochs at ladder level >= 2
    ladder_peak: int = 0             # highest ladder level reached
    # multi-LoRA adapter serving (ClusterConfig.adapters)
    adapter_loads: int = 0           # hot-loads performed fleet-wide
    adapter_evictions: int = 0       # residency evictions/swaps
    adapter_load_failures: int = 0   # loads that fell back to base model
    adapter_load_time_s: float = 0.0  # total DMA seconds charged to rounds
    adapter_versions_published: int = 0  # registry publish events
    adapter_versions_served: int = 0  # distinct (tenant, version) completed


class ClusterSim:
    """Owns the fleet, the shared clock and the prefill placement; applies
    both autoscaler control loops' decisions."""

    def __init__(self, cfg_inf: ModelConfig, cfg_ft: ModelConfig,
                 sim: SimConfig, cluster: ClusterConfig):
        self.cfg_inf = cfg_inf
        self.cfg_ft = cfg_ft
        self.sim = sim
        self.cluster = cluster
        spec = InstanceSpec(tp=sim.tp)
        self.predictor, _ = fit_predictor(cfg_inf, sim)
        # thread the experiment seed into the router (like the CostModel
        # seed): an explicit RouterConfig.seed — including 0 — wins, the
        # None default derives
        rcfg = cluster.router
        if rcfg.seed is None:
            rcfg = dataclasses.replace(
                rcfg, seed=sim.seed + ROUTER_SEED_SALT)
        self.router_cfg = rcfg
        self.mode = cluster.resolved_mode()
        placement_cls = api.resolve_policy("prefill", self.mode)
        self.placement: api.PrefillPlacement = placement_cls.build(self)
        # ---- multi-LoRA adapter serving (ClusterConfig.adapters) --------
        acfg = cluster.adapters
        self.adapter_registry: Optional[AdapterRegistry] = None
        self._adapter_inst_cfg: Optional[InstanceAdapterConfig] = None
        self._adapter_ids: List[int] = []
        adapter_policy = None
        if acfg is not None:
            self.adapter_registry = AdapterRegistry()
            a_bytes = adapter_bytes(cfg_ft, acfg.rank)
            # chunk geometry matches every serving instance's allocator
            # (same cfg_inf): ceil the adapter into whole chunks so its
            # charge competes honestly with KV admission
            chunk_bytes = cfg_inf.num_layers * 2 * BLOCK_BYTES
            self._adapter_inst_cfg = InstanceAdapterConfig(
                chunks=max(math.ceil(a_bytes / chunk_bytes), 1),
                load_time_s=CostModel(cfg_inf, spec).adapter_load_time(
                    a_bytes),
                max_loaded=acfg.max_loaded)
            adapter_policy = api.resolve_policy(
                "adapter_placement", acfg.policy)(rcfg)
        self.router = ClusterRouter(
            rcfg, CostModel(cfg_inf, spec, seed=sim.seed + 7),
            predictor=self.predictor, placement=self.placement,
            adapter_policy=adapter_policy,
            adapter_registry=self.adapter_registry)
        # ---- cache-summary gossip plane (ClusterConfig.gossip) ----------
        self.gossip_plane: Optional[GossipPlane] = None
        self._next_gossip_pub: Dict[int, float] = {}
        if cluster.gossip is not None:
            self.gossip_plane = GossipPlane(cluster.gossip)
            self.router.gossip = self.gossip_plane
        self.autoscaler = Autoscaler(cluster.autoscaler)
        self.autoscaler.prefill_ttft_slo_s = rcfg.ttft_slo_s
        self._next_id = 0
        self._fleet_timeline: List[Tuple[float, int, int]] = []
        self._peak_total = 0
        # ---- failure layer (ClusterConfig.failures) ---------------------
        f = cluster.failures
        self._ckpt_interval = f.checkpoint_interval_s if f is not None \
            else 0.0
        self._ckpt_dir: Optional[Path] = None
        self._ckpt_time_s = 0.0
        if self._ckpt_interval > 0:
            if f.checkpoint_dir is not None:
                self._ckpt_dir = Path(f.checkpoint_dir)
            else:
                self._ckpt_tmp = tempfile.TemporaryDirectory(
                    prefix="repro_ckpt_")
                self._ckpt_dir = Path(self._ckpt_tmp.name)
            self._ckpt_time_s = CostModel(cfg_ft, spec).checkpoint_time()
        self._pending_kills: List[Tuple[float, int]] = []  # (deadline, iid)
        self._failures = 0
        self._preemptions = 0
        self._requeued = 0
        self._requeue_rejected = 0
        self._ft_lost_iterations = 0.0
        # ---- survivability layer (migration + degradation ladder) -------
        mig = cluster.migration
        self._migration_on = mig is not None and mig.bw_gbps > 0
        self._mig_policy: Optional[api.MigrationPolicy] = None
        if self._migration_on:
            self._mig_policy = api.resolve_policy(
                "migration", mig.policy)()
        # rid -> (dest inst id, tokens shipped, transfer-complete?); filled
        # at the preemption warning, consumed at the deadline kill. Stale
        # entries for requests that finish during the drain window are
        # harmless — rids are never reused
        self._mig_plan: Dict[int, Tuple[int, int, bool]] = {}
        self._migrated = 0
        self._migration_reprefills = 0
        self._migrated_tokens = 0
        deg = cluster.degradation
        self._ladder_level = 0
        self._ladder_peak = 0
        self._breaker_epochs = 0
        self._shed_epochs = 0
        self._shed = 0
        self._retry_heap: List[Tuple[float, int, Request]] = []
        self._shed_rng = None
        if deg is not None:
            # own jitter stream: creating it only when the ladder exists
            # keeps the deg=None path bit-identical to PR 6
            self._shed_rng = np.random.default_rng(
                deg.seed if deg.seed is not None
                else sim.seed + SHED_SEED_SALT)
        if sim.mode == "separate":
            for _ in range(max(cluster.n_initial - 1, 1)):
                self._spawn(0.0, role="decode", colocate=False)
            self._spawn(0.0, role="finetune", serves_inference=False)
        else:
            for _ in range(cluster.n_initial):
                self._spawn(0.0, role="colocated")

    # ------------------------------------------------------------ fleet --
    def _spawn(self, t: float, role: str, colocate: bool = True,
               serves_inference: bool = True) -> DecodeInstanceSim:
        sim = self.sim
        overrides = self.cluster.instance_overrides
        if self._next_id < len(overrides) and overrides[self._next_id]:
            sim = dataclasses.replace(sim, **overrides[self._next_id])
        ckpt = None
        if colocate and self._ckpt_interval > 0:
            # failure injection is on: the finetune job commits progress
            # periodically so a kill rolls back to the last commit
            ckpt = FinetuneCheckpointer(
                self._ckpt_dir / f"inst_{self._next_id}",
                interval_s=self._ckpt_interval,
                commit_time_s=self._ckpt_time_s, t0=t)
        inst = DecodeInstanceSim(
            self._next_id, self.cfg_inf if serves_inference else self.cfg_ft,
            self.cfg_ft if colocate else None, sim,
            self.predictor, self.sim.seed + self._next_id,
            serves_inference=serves_inference, t0=t, role=role,
            prefix_cache=self.cluster.prefix_cache, ckpt=ckpt,
            adapters=self._adapter_inst_cfg,
            **self.placement.spawn_kwargs(self, serves_inference))
        # a joiner during an active breaker epoch inherits the pause
        inst.ft_breaker = self._ladder_level >= 1
        self._next_id += 1
        self.router.add_instance(inst, now=t)
        return inst

    def _serving(self) -> List[DecodeInstanceSim]:
        return self.router.serving_instances()

    def _snapshots(self) -> List[InstanceSnapshot]:
        return [InstanceSnapshot(
            inst_id=i.inst_id, role=i.role, load=i.load(),
            active=len(i.active), colocatable=i.colocate,
            can_serve=i.serves_inference, draining=i.draining)
            for i in self.router.instances.values()]

    def _ft_backlog(self, t: float) -> float:
        """Finetune demand minus progress, in iterations. With no explicit
        target, a dedicated/colocated job is treated as always-hungry."""
        target = self.cluster.autoscaler.ft_target_iters_per_s
        done = sum(i.ft.iterations for i in self.router.all_instances()
                   if i.ft is not None)
        if target <= 0:
            return 1.0               # best-effort: backlog never empties
        return max(target * t - done, 0.0)

    def apply_decision(self, d: ScaleDecision, t: float) -> None:
        """Apply one decode-loop decision (also called by placements that
        escalate to fleet growth, e.g. chunked-budget maxed)."""
        insts = self.router.instances
        if d.action == "add_instance":
            role = "colocated" if self.sim.mode == "harli" else "decode"
            self._spawn(t, role=role, colocate=self.sim.mode == "harli")
            self.placement.on_scale_up(self, t)
        elif d.action == "remove_instance":
            inst = insts.get(d.target)
            # guard at application time too: never drain below the floor
            n_serving = len(self._serving())
            if inst is not None and not inst.draining \
                    and n_serving > self.cluster.autoscaler.min_decode:
                inst.draining = True
        elif d.action == "to_decode":
            inst = insts.get(d.target)
            if inst is not None and inst.role == "colocated":
                inst.set_role("decode")
        elif d.action == "to_colocated":
            inst = insts.get(d.target)
            if inst is not None and inst.colocate and inst.serves_inference:
                inst.set_role("colocated")
        elif d.action == "to_finetune":
            inst = insts.get(d.target)
            if inst is not None and inst.colocate \
                    and len(self._serving()) > \
                    self.cluster.autoscaler.min_decode:
                inst.set_role("finetune")

    # ------------------------------------------------------------- loop --
    def run(self, reqs: List[Request],
            duration: Optional[float] = None) -> ClusterResult:
        cl = self.cluster
        pending = sorted(reqs, key=lambda r: (r.arrival, r.rid))
        if duration is None:
            last = max((r.arrival for r in reqs), default=0.0)
            duration = last + 30.0
        t, qi = 0.0, 0
        next_control = cl.autoscaler.interval_s
        failsched = FailureSchedule(cl.failures, duration) \
            if cl.failures is not None else None
        if self.adapter_registry is not None:
            # every tenant ships a v1 adapter at t=0 (both the continuous
            # and the static arm serve adapters from the start; only the
            # finetune->publish stream below differs)
            self._adapter_ids = sorted({r.adapter_id for r in reqs
                                        if r.adapter_id >= 0})
            for aid in self._adapter_ids:
                self.adapter_registry.publish(aid, 1, 0.0)
        while t < duration:
            epoch_end = min(t + cl.tick_s, duration)
            qi = self._dispatch_arrivals(pending, qi, epoch_end)
            # prefill stage first: completions in this epoch reach their
            # decode instance before it steps through the epoch
            self.router.pump_prefill(epoch_end)
            for inst in list(self.router.instances.values()):
                while inst.t < epoch_end:
                    inst.step(epoch_end)
                if inst.drained:
                    self.router.retire(inst.inst_id)
            self.placement.retire(self, epoch_end)
            if self.gossip_plane is not None:
                self._gossip_tick(epoch_end)
            if self.adapter_registry is not None \
                    and cl.adapters.continuous:
                self._publish_tick(epoch_end)
            if failsched is not None:
                # kills land after the epoch's stepping and BEFORE the
                # control slot: the autoscaler's decode loop sees the
                # shrunken snapshot the same epoch and replaces capacity
                self._apply_failures(failsched, epoch_end)
            if cl.degradation is not None:
                # ladder after failures, before the control slot: the
                # autoscaler and the breaker react to the same signal in
                # the same epoch (the ladder is faster — no cooldown)
                self._ladder_tick(epoch_end)
            if cl.autoscale and epoch_end + 1e-9 >= next_control:
                viol = self.router.recent_violation_frac()
                d = self.autoscaler.evaluate(
                    epoch_end, self._snapshots(), viol,
                    self._ft_backlog(epoch_end))
                self.apply_decision(d, epoch_end)
                # the placement's own control slot (pool sizing / chunk-
                # budget tuning / idle in chained mode)
                self.placement.control(self, epoch_end, viol)
                # re-sync the deadline past this epoch instead of a single
                # increment: with interval_s < tick_s the old += fell
                # unboundedly behind the clock (one evaluation per epoch
                # either way, so decision logs stay bit-identical)
                if cl.autoscaler.interval_s > 0:
                    while next_control <= epoch_end + 1e-9:
                        next_control += cl.autoscaler.interval_s
            t = epoch_end
            self._fleet_point(t, self._serving())
        # requests still backing off at trace end never dispatched: record
        # them as hard-rejected so offered-request accounting stays honest
        for _, _, req in sorted(self._retry_heap):
            self.router.reject_shed(req)
        self._retry_heap = []
        self.router.check_conservation()
        return self._result(duration)

    def _gossip_tick(self, t: float) -> None:
        """Gossip pump: each serving instance with a prefix cache
        publishes a fresh digest when its per-instance period elapses
        (first publish on the first epoch after spawn). Iteration is in
        instance-id order, so the plane's state — and every routing
        decision read from it — is deterministic per seed."""
        plane = self.gossip_plane
        period = plane.cfg.period_s
        for iid in sorted(self.router.instances):
            inst = self.router.instances[iid]
            if inst.prefix_cache is None or not inst.serves_inference \
                    or inst.role == "finetune":
                continue
            due = self._next_gossip_pub.get(iid, 0.0)
            if t + 1e-9 >= due:
                plane.publish(iid, t, inst.prefix_cache.tree)
                self._next_gossip_pub[iid] = t + period

    def _publish_tick(self, t: float) -> None:
        """Continuous deployment: the fleet's finetune iterations train
        the tenants' adapters round-robin; every ``publish_every_iters``
        per-tenant iterations a new version lands in the registry (and is
        served by every dispatch from the next epoch on). Idempotent —
        ``publish`` ignores non-increasing versions."""
        if not self._adapter_ids:
            return
        total = sum(i.ft.iterations for i in self.router.all_instances()
                    if i.ft is not None)
        per_tenant = total / len(self._adapter_ids)
        ver = 1 + int(per_tenant
                      / self.cluster.adapters.publish_every_iters)
        for aid in self._adapter_ids:
            self.adapter_registry.publish(aid, ver, t)

    def _dispatch_arrivals(self, pending: List[Request], qi: int,
                           epoch_end: float) -> int:
        """Offer this epoch's traffic to the router in time order: fresh
        arrivals merged with shed requests whose backoff elapsed (arrival
        wins ties). At ladder level 2 the shed gate replaces dispatch.
        With no degradation ladder this reduces exactly to the plain
        arrival scan (the retry heap stays empty)."""
        deg = self.cluster.degradation
        while True:
            t_arr = pending[qi].arrival if qi < len(pending) else None
            t_re = self._retry_heap[0][0] if self._retry_heap else None
            if t_arr is not None and (t_re is None or t_arr <= t_re):
                if t_arr > epoch_end:
                    break
                req, now = pending[qi], t_arr
                qi += 1
            elif t_re is not None:
                if t_re > epoch_end:
                    break
                now, _, req = heapq.heappop(self._retry_heap)
            else:
                break
            if deg is not None and deg.shed and self._ladder_level >= 2:
                self._shed_request(req, now, deg)
            else:
                self.router.dispatch(req, now)
        return qi

    def _shed_request(self, req: Request, now: float,
                      deg: DegradationConfig) -> None:
        """Ladder level 2: push the request back with seeded jittered
        exponential backoff; past the retry cap it is hard-rejected. The
        backoff lands in TTFT — the request's arrival stays its original
        arrival, so the wait is priced, not hidden."""
        req.retries += 1
        if req.retries > deg.max_retries:
            self.router.reject_shed(req)
            return
        backoff = deg.backoff_base_s \
            * deg.backoff_mult ** (req.retries - 1)
        if deg.backoff_jitter > 0:
            backoff *= 1.0 + deg.backoff_jitter \
                * float(self._shed_rng.uniform(-1.0, 1.0))
        heapq.heappush(self._retry_heap, (now + backoff, req.rid, req))
        self._shed += 1

    def _ladder_tick(self, now: float) -> None:
        """One deterministic ladder step per epoch off the fleet's recent
        request-level SLO-violation fraction: escalate 0 -> 1 (finetune
        breaker) -> 2 (load shedding), de-escalate one level once the
        signal recovers. Request-level, not round-level: the QoS decode
        scheduler keeps rounds under the TPOT budget by construction, so
        overload shows up as TTFT misses on completed requests."""
        deg = self.cluster.degradation
        viol = self.router.recent_slo_violation_frac()
        lvl = self._ladder_level
        if lvl > 0 and viol <= deg.resume_viol_frac:
            lvl -= 1
        elif lvl == 0 and viol >= deg.breaker_viol_frac:
            lvl = 1
        elif lvl == 1 and deg.shed and viol >= deg.shed_viol_frac:
            lvl = 2
        if lvl != self._ladder_level:
            self._ladder_level = lvl
            for inst in self.router.instances.values():
                inst.ft_breaker = lvl >= 1
        self._ladder_peak = max(self._ladder_peak, lvl)
        if lvl >= 1:
            self._breaker_epochs += 1
        if lvl >= 2:
            self._shed_epochs += 1

    # -------------------------------------------------------- failures --
    def _victim_candidates(self) -> List[Tuple[str, int]]:
        """Eligible kill victims, deterministically ordered: live instances
        (not already under a preemption notice) and, in pooled mode, active
        prefill workers. The last inference-capable instance is protected —
        a fleet with zero decode capacity has no defined hand-off target
        (real clusters would stall, not crash; the simulator skips the
        event instead)."""
        insts = [i for i in self.router.instances.values()
                 if i.preempt_deadline < 0]
        serving = {i.inst_id for i in insts
                   if i.serves_inference and i.role != "finetune"
                   and not i.draining}
        capable = {i.inst_id for i in insts
                   if i.serves_inference and i.role != "finetune"}
        protected = set()
        if len(serving) <= 1:
            protected |= serving
        if len(capable) <= 1:
            protected |= capable
        out: List[Tuple[str, int]] = [
            ("inst", i.inst_id) for i in insts
            if i.inst_id not in protected]
        pool = self.router.pool
        if pool is not None:
            out += [("worker", w.wid) for w in pool.active_workers()]
        out.sort()
        return out

    def _apply_failures(self, sched: FailureSchedule, now: float) -> None:
        """Consume the schedule's events due this epoch: hard kills, or
        preemption notices (warning_s > 0) whose deadline kill fires in a
        later epoch unless the victim drained first."""
        cfg = self.cluster.failures
        due = [pk for pk in self._pending_kills if pk[0] <= now + 1e-9]
        self._pending_kills = [pk for pk in self._pending_kills
                               if pk[0] > now + 1e-9]
        for deadline, iid in sorted(due):
            inst = self.router.instances.get(iid)
            if inst is None:
                continue             # drained and retired before deadline
            capable = [i for i in self.router.instances.values()
                       if i.serves_inference and i.role != "finetune"]
            if inst.serves_inference and inst.role != "finetune" \
                    and len(capable) <= 1:
                # the notice elapsed but no replacement capacity exists
                # yet: the stay-of-execution defers the kill one epoch —
                # the fleet never loses its last inference-capable host
                self._pending_kills.append(
                    (now + self.cluster.tick_s, iid))
                continue
            self._kill_instance(iid, now)
        for tk in sched.pop_due(now):
            cand = self._victim_candidates()
            if not cand:
                continue
            kind, vid = sched.pick(cand)
            if kind == "worker":
                self._kill_pool_worker(vid, now)
            elif cfg.warning_s > 0:
                inst = self.router.instances[vid]
                deadline = tk + cfg.warning_s
                inst.begin_preempt(deadline)
                self._pending_kills.append((deadline, vid))
                self._preemptions += 1
                if self._migration_on:
                    self._migrate_victim(inst, now, deadline)
            else:
                self._kill_instance(vid, now)
        # separate mode: a killed dedicated finetune instance is replaced
        # by the training job's own scheduler (the autoscaler's decode
        # loop only replaces serving capacity); the job restarts from its
        # last checkpoint on the fresh host
        if self.sim.mode == "separate" and not any(
                i.ft is not None
                for i in self.router.instances.values()):
            self._spawn(now, role="finetune", serves_inference=False)

    def _migrate_victim(self, victim: DecodeInstanceSim, now: float,
                        deadline: float) -> None:
        """Plan the live (pre-copy) KV migration off a warned instance.
        The victim keeps serving until the deadline while its in-flight
        KV streams to the peers the migration policy picks, serialized on
        the victim's egress link smallest-context-first (maximizing how
        many transfers win the race). Nothing moves yet — a request that
        finishes during the drain window never needed to move, and KV
        grown during the window rides the pre-copy delta stream. At the
        deadline ``_kill_instance`` executes the plan: requests whose
        transfer completed resume on their destination without
        re-prefill; the first transfer that cannot finish consumes the
        link to the deadline and ships what fits as a partial tail (the
        destination re-prefills only the unsent remainder); everything
        behind it falls back to the PR 6 re-prefill path."""
        mig = self.cluster.migration
        cand = [i for i in self.router.serving_instances()
                if i.inst_id != victim.inst_id]
        if not cand:
            return                   # no peer: drain in place (PR 6)
        cm = self.router.prefill_cm
        bpt = self.cfg_inf.cache_bytes_per_token()

        def kv_tokens(req: Request, kind: str) -> int:
            # resident KV on the victim: full context for decoding /
            # prefill-complete requests, chunk progress (+ cached prefix)
            # for mid-chunked-prefill ones
            if kind == "chunked":
                return req.cache_hit_tokens + req.prefilled_tokens
            return req.context_len

        items = victim.migratable()
        items.sort(key=lambda it: (kv_tokens(it[0], it[1]), it[0].rid))
        t_link = now
        for req, kind, ready in items:
            toks = kv_tokens(req, kind)
            # a pending request's KV only exists once its prefill lands
            start = max(t_link, ready) if kind == "pending" else t_link
            xfer = cm.kv_migration_time(toks, mig.bw_bytes, mig.setup_s)
            # destination picked at plan time; in-flight transfers are
            # not yet resident, so planning does not feed back into the
            # policy's headroom signal
            dest = self._mig_policy.pick_dest(req, cand, self.router)
            if start + xfer <= deadline:
                t_link = start + xfer
                self._mig_plan[req.rid] = (dest.inst_id, toks, True)
                continue
            # loser: ship what the link can push before the kill as a
            # partial tail; the request drains in place and is requeued
            # (tail-credited) at the deadline
            window = deadline - start - mig.setup_s
            sent = min(int(window * mig.bw_bytes / bpt), toks) \
                if window > 0 else 0
            if sent > 0:
                self._mig_plan[req.rid] = (dest.inst_id, sent, False)
            break                    # the link is consumed to the deadline

    def _kill_instance(self, iid: int, now: float) -> None:
        """Hard-kill one instance: strip its in-flight work, remove it
        from the fleet, and execute the migration plan over whatever is
        still in flight — a completed transfer resumes on its destination
        in the stage it left (no re-prefill, the kill -> re-admit gap is
        priced into its token timeline); a partial transfer re-prefills
        only its unsent tail on the destination; everything else
        re-enters through the router at full length (PR 6)."""
        inst = self.router.instances[iid]
        warned = inst.preempt_deadline >= 0
        # stage snapshot before the kill strips the queues: the plan's
        # kind may be stale (a chunked prefill can finish into pending/
        # active during the drain window)
        kinds = {req.rid: kind for req, kind, _ in inst.migratable()} \
            if warned and self._migration_on else {}
        lost, ft_lost = inst.kill(now)
        self._ft_lost_iterations += ft_lost
        self.router.kill_instance(iid)
        if self.gossip_plane is not None:
            # the dead cache's advertisement must not keep attracting
            # traffic until the staleness bound expires
            self.gossip_plane.drop(iid)
            self._next_gossip_pub.pop(iid, None)
        self._failures += 1
        if not lost:
            return
        remnants: List[Request] = []
        tails: Dict[int, Tuple[int, int]] = {}
        for r in sorted(lost, key=lambda q: q.rid):
            plan = self._mig_plan.pop(r.rid, None)
            if plan is not None and plan[2]:
                dest = self.router.instances.get(plan[0])
                if dest is not None and dest.serves_inference \
                        and dest.role != "finetune" and not dest.draining:
                    self.router.migrate(r, dest, now,
                                        kinds.get(r.rid, "active"))
                    self._migrated += 1
                    self._migrated_tokens += plan[1]
                    continue
                plan = None          # the copy's host died too: full re-prefill
            if plan is not None and plan[1] > 0:
                tails[r.rid] = (plan[0], plan[1])
                self._migrated_tokens += plan[1]
            remnants.append(r)
        if remnants:
            if self._migration_on and warned:
                self._migration_reprefills += len(remnants)
            n = self.router.requeue_failed(remnants, now, tails=tails)
            self._requeued += n
            self._requeue_rejected += len(remnants) - n

    def _kill_pool_worker(self, wid: int, now: float) -> None:
        """Kill one pooled prefill worker: the batch it was running dies
        with it, so those requests are recalled from the decode instances
        awaiting them and resubmitted to the (cluster-wide) queue."""
        pool = self.router.pool
        batch_rids = pool.kill_worker(wid, now)
        self._failures += 1
        reqs = []
        for rid in batch_rids:
            req = self.router.recall_pending(rid)
            if req is not None:
                reqs.append(req)
        if reqs:
            n = self.router.requeue_failed(reqs, now)
            self._requeued += n
            self._requeue_rejected += len(reqs) - n

    def _fleet_point(self, t: float, serving) -> None:
        self._fleet_timeline.append(
            (t, len(serving),
             sum(1 for i in serving if i.role == "colocated")))
        self._peak_total = max(self._peak_total,
                               len(self.router.instances))
        self.placement.record_timeline(self, t)

    def _result(self, duration: float) -> ClusterResult:
        for inst in self.router.all_instances():
            inst.collect_tpot()
        res = ClusterResult(mode=self.sim.mode,
                            stats=self.router.stats(duration))
        minibatch = self.sim.micro_batch * self.sim.accum
        for inst in self.router.all_instances():
            if inst.ft is not None:
                res.ft_iterations += inst.ft.iterations
                res.ft_stall_rounds += inst.ft.stall_rounds
            res.tpot.extend(inst.result_tpot)
        res.ft_throughput = res.ft_iterations / duration * minibatch
        if res.tpot:
            # same limit the router's per-request TPOT attainment uses
            rcfg = self.router.cfg
            lim = rcfg.tpot_slo_s * rcfg.tpot_slack
            res.qos_violation_frac = \
                sum(1 for x in res.tpot if x > lim) / len(res.tpot)
        res.fleet_timeline = self._fleet_timeline
        res.decisions = self.autoscaler.decisions
        res.failures = self._failures
        res.preemptions = self._preemptions
        res.requeued_requests = self._requeued
        res.requeue_rejected = self._requeue_rejected
        res.ft_lost_iterations = self._ft_lost_iterations
        res.migrated_requests = self._migrated
        res.migration_reprefills = self._migration_reprefills
        res.migrated_kv_tokens = self._migrated_tokens
        res.shed_requests = self._shed
        res.shed_rejected = res.stats.shed_rejected
        res.breaker_epochs = self._breaker_epochs
        res.shed_epochs = self._shed_epochs
        res.ladder_peak = self._ladder_peak
        res.checkpoint_commits = sum(
            i.ckpt.commits for i in self.router.all_instances()
            if i.ckpt is not None)
        res.final_fleet = len(self.router.instances)
        res.peak_fleet = max(self._peak_total, res.final_fleet)
        self.placement.finalize(self, res)
        for inst in self.router.all_instances():
            if inst.prefix_cache is not None:
                res.prefix_hits += inst.prefix_cache.stats.hits
                res.prefix_misses += inst.prefix_cache.stats.misses
                res.prefix_hit_tokens += inst.prefix_cache.stats.hit_tokens
                res.prefix_shared_hit_tokens += \
                    inst.prefix_cache.stats.shared_hit_tokens
            if inst.adapters is not None:
                res.adapter_loads += inst.adapters.loads
                res.adapter_evictions += inst.adapters.evictions
                res.adapter_load_failures += inst.adapters.load_failures
                res.adapter_load_time_s += inst.adapters.load_time_total_s
        res.dispatch_peeks = self.router.dispatch_peeks
        if self.gossip_plane is not None:
            res.gossip_published = self.gossip_plane.published
            res.gossip_bytes = self.gossip_plane.bytes_published
            res.gossip_stale_discards = self.gossip_plane.stale_discards
            res.gossip_max_used_age = self.gossip_plane.max_used_age
        if self.adapter_registry is not None:
            res.adapter_versions_published = \
                self.adapter_registry.versions_published
            res.adapter_versions_served = sum(
                tn.versions_served for tn in res.stats.tenants.values())
        return res


def simulate_cluster(cfg_inf: ModelConfig, cfg_ft: ModelConfig,
                     reqs: List[Request], sim: SimConfig,
                     cluster: Optional[ClusterConfig] = None,
                     duration: Optional[float] = None) -> ClusterResult:
    """One seeded cluster experiment (deterministic for a fixed seed)."""
    cs = ClusterSim(cfg_inf, cfg_ft, sim, cluster or ClusterConfig())
    return cs.run(reqs, duration)
