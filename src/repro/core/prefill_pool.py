"""Disaggregated prefill pool: TTFT-deadline-aware batched prefill workers.

DistServe (Zhong et al., OSDI'24) shows goodput depends on scaling and
scheduling prefill and decode *independently*; PR 1's cluster layer instead
serialized prefill as one chain per decode instance, so TTFT was an artifact
of decode placement. This module makes prefill a scheduled resource of its
own: a pool of workers shares one cluster-wide queue, each worker runs
*fused batched* prefills (``CostModel.prefill_batch_latency`` — token work
additive, weight stream paid once), and the queue is ordered by TTFT
deadline.

Queue ordering ("edf"): earliest *latest-feasible-start* first, i.e.
``arrival + ttft_slo - estimated_prefill_compute``. With a uniform SLO,
textbook EDF over ``arrival + ttft_slo`` degenerates to FIFO; subtracting
each request's own prefill estimate keeps the ordering deadline-aware for
ragged prompts — a long prompt must start earlier than a short one that
arrived just before it to make the same TTFT SLO. Under overload, plain EDF
(and FIFO) burn capacity on requests that can no longer attain their
deadline, so EDF here additionally *demotes doomed requests*: at dispatch
time, a request whose deadline is already infeasible yields to every
still-feasible one (it is served, just last) — the overload behaviour that
actually moves SLO attainment and goodput. "fifo" (strict arrival order) is
kept as the comparison baseline.

Workers mirror decode-instance lifecycle: they can be added at any time,
put into draining (no new batches), and retired once idle — the second
autoscaler control loop (core/autoscaler.py, ``evaluate_prefill``) drives
both transitions against TTFT headroom and queue depth.

Conservation invariant (tested): every submitted request is prefilled
exactly once or still queued — never dropped, never duplicated — and each
worker's completion times are monotone non-decreasing.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Deque, Dict, List, Tuple

import numpy as np

from repro.core.costmodel import CostModel
from repro.serving.request import Request

ORDERINGS = ("edf", "fifo")


@dataclasses.dataclass
class PrefillPoolConfig:
    n_workers: int = 2               # pool size at t=0
    max_batch: int = 4               # fused-prefill batch cap per launch
    # token budget per fused launch: prefill is compute-bound past a few
    # hundred tokens (work additive — fusing a long prompt onto an urgent
    # one only delays the urgent one), so only short prompts below the
    # compute/memory crossover are batched, where fusing is ~free and
    # amortizes the weight stream + launch overhead
    max_batch_tokens: int = 512
    ordering: str = "edf"            # "edf" | "fifo"
    wait_window_s: float = 15.0      # recency horizon, TTFT-headroom signal


@dataclasses.dataclass
class PrefillWorker:
    wid: int
    free_at: float = 0.0             # end of the batch currently running
    busy_s: float = 0.0
    n_prefilled: int = 0
    n_batches: int = 0
    draining: bool = False
    last_done: float = 0.0           # monotone per worker (tested)
    # failure layer: prefills whose output KV was later lost to a kill
    # (the request was resubmitted); rids of the batch now running
    n_invalidated: int = 0
    current_batch: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class PrefillPoolSnapshot:
    """Control-loop view of the pool (autoscaler input)."""
    n_workers: int                   # active (non-draining)
    n_draining: int
    queue_depth: int
    backlog_s: float                 # scheduled work beyond `now`, summed
    wait_p99: float                  # recent arrival -> prefill-done waits


class PrefillPool:
    """Shared-queue prefill tier over a mutable set of workers.

    Driven by the cluster event loop: ``submit`` on admission, ``pump``
    once per epoch — it assigns queued requests to free workers in deadline
    order and returns ``(request, ready_time)`` completions for the decode
    stage. A batch started before ``until`` runs atomically and may finish
    past it (same convention as decode rounds)."""

    def __init__(self, cfg: PrefillPoolConfig, cm: CostModel,
                 ttft_slo_s: float = 4.0, t0: float = 0.0):
        assert cfg.ordering in ORDERINGS, cfg.ordering
        assert cfg.n_workers >= 1 and cfg.max_batch >= 1
        self.cfg = cfg
        self.cm = cm
        self.ttft_slo_s = ttft_slo_s
        self.workers: Dict[int, PrefillWorker] = {}
        self.retired: Dict[int, PrefillWorker] = {}
        self.killed: Dict[int, PrefillWorker] = {}   # failure-layer victims
        self._next_wid = 0
        for _ in range(cfg.n_workers):
            self.add_worker(t0)
        # main queue of (order_key, rid, request), heap in key order; a
        # request classified doomed (deadline infeasible) moves to the
        # doomed heap permanently — batch start times are non-decreasing,
        # so doomed-ness is absorbing and each item is classified once
        self._queue: List[Tuple[float, int, Request]] = []
        self._doomed: List[Tuple[float, int, Request]] = []
        # min-arrival tracking with lazy deletion (rid still queued?)
        self._arr_heap: List[Tuple[float, int]] = []
        self._queued_rids: set = set()
        self._submitted: Dict[int, Request] = {}
        self._done: Dict[int, int] = {}            # rid -> worker id
        self._waits: Deque[Tuple[float, float]] = deque()  # (done_t, wait)

    # ------------------------------------------------------------ workers --
    def add_worker(self, now: float = 0.0) -> int:
        w = PrefillWorker(wid=self._next_wid, free_at=now, last_done=now)
        self.workers[w.wid] = w
        self._next_wid += 1
        return w.wid

    def active_workers(self) -> List[PrefillWorker]:
        return [w for w in self.workers.values() if not w.draining]

    def drain_worker(self, min_workers: int = 1) -> int:
        """Mark one worker draining (it finishes its running batch but takes
        no new ones). Picks the soonest-idle worker; refuses to go below
        ``min_workers`` active. Returns the wid, or -1 if refused."""
        cand = self.active_workers()
        if len(cand) <= min_workers:
            return -1
        w = min(cand, key=lambda w: (w.free_at, w.wid))
        w.draining = True
        return w.wid

    def retire_drained(self, now: float) -> List[int]:
        """Move draining workers whose last batch has finished out of the
        pool (they stay visible for accounting)."""
        out = []
        for wid in list(self.workers):
            w = self.workers[wid]
            if w.draining and w.free_at <= now:
                self.retired[wid] = self.workers.pop(wid)
                out.append(wid)
        return out

    def kill_worker(self, wid: int, now: float) -> List[int]:
        """Hard worker failure (cluster failure layer): the worker leaves
        the pool immediately. Returns the rids of the batch it was still
        running at ``now`` — their prefill output dies with the host, so
        the caller must recall them from their decode instances and
        resubmit. Queued work is untouched (the queue is cluster-wide)."""
        w = self.workers.pop(wid)
        w.draining = True
        self.killed[wid] = w
        if w.free_at <= now:
            return []
        return [rid for rid in w.current_batch
                if self._done.get(rid) == wid]

    def has_prefill_record(self, rid: int) -> bool:
        """True when ``rid`` holds a completed-prefill record that must be
        forgotten before the request may be resubmitted."""
        return rid in self._done

    def forget(self, rid: int) -> None:
        """Erase one prefill record after its output KV was lost to a
        failure, so the request can be submitted again. The worker's
        throughput counter keeps the work it did — the conservation audit
        tracks invalidations separately."""
        wid = self._done.pop(rid)
        w = self.workers.get(wid) or self.retired.get(wid) \
            or self.killed.get(wid)
        w.n_invalidated += 1

    def all_workers(self) -> List[PrefillWorker]:
        return list(self.workers.values()) + list(self.retired.values()) \
            + list(self.killed.values())

    # -------------------------------------------------------------- queue --
    def _order_key(self, req: Request) -> float:
        if self.cfg.ordering == "fifo":
            return req.arrival
        # EDF over the latest feasible start time for the TTFT deadline.
        # Costing uses the EFFECTIVE prompt (minus any prefix-cache credit
        # the router granted at admission): a sticky hit genuinely needs
        # less compute, so its deadline is later than raw length suggests.
        return req.arrival + self.ttft_slo_s \
            - self.cm.prefill_latency(req.effective_prompt_len)

    def submit(self, req: Request, now: float) -> None:
        # a genuine double-submit is still an error; a RESUBMIT after a
        # failure is legal once forget() erased the lost prefill record
        assert req.rid not in self._queued_rids \
            and req.rid not in self._done, "request submitted twice"
        self._submitted[req.rid] = req
        heapq.heappush(self._queue, (self._order_key(req), req.rid, req))
        heapq.heappush(self._arr_heap, (req.arrival, req.rid))
        self._queued_rids.add(req.rid)

    def _min_arrival(self) -> float:
        """Earliest arrival among queued requests (doomed included), with
        lazy deletion of already-prefilled entries."""
        while self._arr_heap and self._arr_heap[0][1] not in self._queued_rids:
            heapq.heappop(self._arr_heap)
        assert self._arr_heap, "min_arrival on an empty queue"
        return self._arr_heap[0][0]

    @property
    def queue_depth(self) -> int:
        return len(self._queue) + len(self._doomed)

    def backlog_s(self, now: float) -> float:
        return sum(max(w.free_at - now, 0.0)
                   for w in self.workers.values())

    def wait_p99(self, now: float) -> float:
        """p99 of arrival->prefill-done waits completed within the recency
        horizon — stale spike-era samples must not keep the autoscaler
        growing the pool after the backlog has cleared. Old samples are
        pruned from the front (done times are near-sorted; the residual
        filter keeps the value exact)."""
        lo = now - self.cfg.wait_window_s
        while self._waits and self._waits[0][0] < lo:
            self._waits.popleft()
        recent = [w for t, w in self._waits if t >= lo]
        if not recent:
            return 0.0
        return float(np.percentile(recent, 99))

    def snapshot(self, now: float) -> PrefillPoolSnapshot:
        return PrefillPoolSnapshot(
            n_workers=len(self.active_workers()),
            n_draining=sum(1 for w in self.workers.values() if w.draining),
            queue_depth=self.queue_depth,
            backlog_s=self.backlog_s(now),
            wait_p99=self.wait_p99(now))

    def _select_batch(self, start: float) -> List[Request]:
        """Pop the next fused batch for a worker starting at ``start``:
        requests that have arrived, in queue-key order, feasible ones
        (deadline still attainable) ahead of doomed ones, fused only while
        the batch stays under the token budget — a long prompt fused onto
        an urgent short one would delay the short one for near-zero
        throughput gain (prefill is compute-bound past a few hundred
        tokens). A request found doomed moves to the doomed heap for good
        (batch starts never decrease), so it is classified exactly once."""
        feas: List[Tuple[float, int, Request]] = []
        deferred: List[Tuple[float, int, Request]] = []
        while self._queue and len(feas) < self.cfg.max_batch:
            item = heapq.heappop(self._queue)
            r = item[2]
            if r.arrival > start:
                deferred.append(item)
            elif self.cfg.ordering == "edf" and \
                    start + self.cm.prefill_latency(
                        r.effective_prompt_len) > \
                    r.arrival + self.ttft_slo_s:
                heapq.heappush(self._doomed, item)
            else:
                feas.append(item)
        # budget-bounded prefix in key order; doomed run only when nothing
        # feasible is waiting (they are served, just last)
        batch: List[Request] = []
        tokens = 0
        if feas:
            for i, item in enumerate(feas):
                r = item[2]
                if batch and tokens + r.effective_prompt_len > \
                        self.cfg.max_batch_tokens:
                    deferred.extend(feas[i:])
                    break
                batch.append(r)
                tokens += r.effective_prompt_len
        else:
            while self._doomed and len(batch) < self.cfg.max_batch:
                r = self._doomed[0][2]
                if batch and tokens + r.effective_prompt_len > \
                        self.cfg.max_batch_tokens:
                    break
                heapq.heappop(self._doomed)
                batch.append(r)
                tokens += r.effective_prompt_len
        for item in deferred:
            heapq.heappush(self._queue, item)
        for r in batch:
            self._queued_rids.discard(r.rid)
        return batch

    # --------------------------------------------------------------- pump --
    def pump(self, until: float) -> List[Tuple[Request, float]]:
        """Assign queued requests to free workers up to ``until``. Returns
        ``(request, prefill_done)`` for every batch *started* before
        ``until`` in completion order (ready times may exceed ``until``)."""
        out: List[Tuple[Request, float]] = []
        while self._queue or self._doomed:
            cand = self.active_workers()
            if not cand:
                break
            w = min(cand, key=lambda w: (w.free_at, w.wid))
            # the worker may only start once free AND something has arrived
            start = max(w.free_at, self._min_arrival())
            if start >= until:
                break
            batch = self._select_batch(start)
            assert batch, "free worker with an arrived request found none"
            lat = self.cm.prefill_batch_latency(
                [r.effective_prompt_len for r in batch])
            done = start + lat
            assert done >= w.last_done - 1e-12
            w.free_at = done
            w.last_done = done
            w.busy_s += lat
            w.n_batches += 1
            w.n_prefilled += len(batch)
            w.current_batch = [r.rid for r in batch]
            for r in batch:
                r.prefill_start = start
                r.prefill_done = done
                r.prefill_worker = w.wid
                assert r.rid not in self._done, "request prefilled twice"
                self._done[r.rid] = w.wid
                self._waits.append((done, done - r.arrival))
                out.append((r, done))
        return out

    # --------------------------------------------------------- invariants --
    def check_conservation(self) -> None:
        """Every submitted request is queued xor prefilled-exactly-once,
        and per-worker throughput accounting matches the completion map."""
        queued = {rid for _, rid, _ in self._queue} \
            | {rid for _, rid, _ in self._doomed}
        assert len(queued) == self.queue_depth, "duplicate in queue"
        assert queued == self._queued_rids
        for rid in self._submitted:
            in_q, is_done = rid in queued, rid in self._done
            assert in_q != is_done, \
                f"request {rid} queued={in_q} done={is_done}"
        assert len(queued) + len(self._done) == len(self._submitted)
        per_worker: Dict[int, int] = {}
        for wid in self._done.values():
            per_worker[wid] = per_worker.get(wid, 0) + 1
        for w in self.all_workers():
            # live records + failure-invalidated ones account for every
            # prefill the worker ever ran
            assert per_worker.get(w.wid, 0) + w.n_invalidated \
                == w.n_prefilled
