"""QoS-guaranteed throughput-maximizing scheduler (paper §6).

Each decode round the scheduler picks the largest finetune quantum k (layer
units fused into the round) whose *predicted* co-located decode latency stays
within the QoS target. Predicting a violation pauses the finetune task
(k = 0, inference preempts everything); a finetune stall on window swaps does
the same (§6.2). A small multiplicative safety margin adapts from observed
latencies (feedback guard against model drift — beyond-paper hardening,
defaults to the paper's behaviour when predictions are accurate).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.predictor import TwoStageLatencyPredictor


@dataclasses.dataclass
class SchedulerConfig:
    qos_s: float = 0.040            # 40 ms TPOT (paper §8.1)
    k_max: int = 10
    safety: float = 0.95            # fraction of QoS budget usable
    margin_adapt: float = 0.05      # feedback step on violations
    margin_floor: float = 0.70


@dataclasses.dataclass
class RoundDecision:
    k: int
    predicted_s: float
    reason: str                      # "ok" | "stalled" | "idle" | "qos"


class QoSScheduler:
    def __init__(self, predictor: TwoStageLatencyPredictor,
                 cfg: SchedulerConfig = SchedulerConfig()):
        self.pred = predictor
        self.cfg = cfg
        self.margin = cfg.safety
        self.violations = 0
        self.rounds = 0
        self.decisions: List[RoundDecision] = []

    def pick(self, bs: int, mean_ctx: float, *, ft_ready: bool,
             ft_units_available: int) -> RoundDecision:
        """Select the finetune quantum for the next decode round."""
        self.rounds += 1
        if bs == 0:
            # no decode work: finetune free-runs (max units per round)
            k = min(self.cfg.k_max, ft_units_available) if ft_ready else 0
            d = RoundDecision(k, 0.0, "idle")
        elif not ft_ready or ft_units_available <= 0:
            d = RoundDecision(0, self.pred.predict_colo(0.0, bs, mean_ctx),
                              "stalled")
        else:
            budget = self.cfg.qos_s * self.margin
            k_best, pred_best = 0, self.pred.predict_colo(0.0, bs, mean_ctx)
            for k in range(min(self.cfg.k_max, ft_units_available), 0, -1):
                p = self.pred.predict_colo(k / self.cfg.k_max, bs, mean_ctx)
                if p <= budget:
                    k_best, pred_best = k, p
                    break
            d = RoundDecision(k_best, pred_best,
                              "ok" if k_best > 0 else "qos")
        self.decisions.append(d)
        return d

    def observe(self, actual_s: float) -> None:
        """Feedback from the finished round: tighten the margin on QoS
        violations, relax it slowly when well under budget."""
        if actual_s > self.cfg.qos_s:
            self.violations += 1
            self.margin = max(self.margin - self.cfg.margin_adapt,
                              self.cfg.margin_floor)
        elif actual_s < 0.8 * self.cfg.qos_s and \
                self.margin < self.cfg.safety:
            self.margin = min(self.margin + self.cfg.margin_adapt / 4,
                              self.cfg.safety)
