"""Logical-axis sharding for the whole model zoo.

Models annotate activations/params with *logical* axis names; a mesh-rules
context (installed by the launcher / dry-run) resolves them to mesh axes and
applies ``with_sharding_constraint``. Without an active context every
``constrain`` is a no-op, so all model code runs unchanged single-device.

Parallelism mapping (production mesh, see DESIGN.md §5):
  batch   -> ("pod", "data")   pure DP (pod axis crosses pods)
  heads / kv_heads / ff / expert / vocab -> "model"   TP / EP
  seq_sp  -> "model"           sequence-parallel residual stream between layers
  rank    -> None              LoRA rank stays replicated (tiny)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

# Default logical->mesh rules for the production meshes. "pod" is folded into
# the batch axes only when the mesh has one.
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,          # sequence dim of *inputs* stays replicated-within-dp
    "seq_sp": "model",    # sequence-parallel residual stream
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "q_per_kv": None,
    "head_dim": None,
    "ff": "model",
    "expert": ("data", "model"),   # full EP when E divides (deepseek: 256)
    "expert_cap": None,
    "vocab": "model",
    "rank": None,
    "layers": None,
    "kv_seq": None,       # KV-cache sequence dim (hillclimb: -> "model")
    "state": None,
    "pages": ("pod", "data"),
}


# FSDP strategy (train cells whose global batch divides the whole mesh):
# activations are purely batch-sharded over every axis; weights are fully
# sharded and GSPMD inserts the per-layer all-gathers. With LoRA (no base
# grads) this removes ALL per-layer activation collectives — see
# EXPERIMENTS.md §Perf cell C.
FSDP_RULES: Dict[str, Axis] = {k: None for k in DEFAULT_RULES}
FSDP_RULES["batch"] = ("pod", "data", "model")
FSDP_RULES["pages"] = ("pod", "data")


class _MeshCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Axis] = {}


_CTX = _MeshCtx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[Dict[str, Axis]] = None):
    """Install a mesh + logical-axis rules for model tracing."""
    prev = (_CTX.mesh, _CTX.rules)
    rules = dict(DEFAULT_RULES if rules is None else rules)
    # Drop rules that reference axes absent from this mesh.
    resolved = {}
    names = set(mesh.axis_names)
    for k, v in rules.items():
        if v is None:
            resolved[k] = None
        elif isinstance(v, str):
            resolved[k] = v if v in names else None
        else:
            kept = tuple(a for a in v if a in names)
            resolved[k] = kept if kept else None
    _CTX.mesh, _CTX.rules = mesh, resolved
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def resolve(logical: Sequence[Optional[str]]) -> P:
    spec = []
    for name in logical:
        if name is None:
            spec.append(None)
        else:
            spec.append(_CTX.rules.get(name))
    return P(*spec)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    n = 1
    for a in axis:
        n *= mesh.shape[a]
    return n


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical axis names; no-op without a mesh.
    Axes that do not divide the dimension evenly are dropped (replicated) —
    e.g. mixtral's 8 experts on a 16-way model axis."""
    if _CTX.mesh is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank mismatch: {x.shape} vs logical {logical}")
    spec = resolve(logical)
    fixed = []
    used: set = set()
    for dim, axis in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        # a mesh axis may appear on at most one dim: first dim wins
        if isinstance(axis, tuple):
            axis = tuple(a for a in axis if a not in used) or None
            if isinstance(axis, tuple) and len(axis) == 1:
                axis = axis[0]
        elif axis in used:
            axis = None
        n = _axis_size(_CTX.mesh, axis)
        keep = axis if (n > 1 and dim % n == 0) else None
        if keep is not None:
            used.update(keep if isinstance(keep, tuple) else (keep,))
        fixed.append(keep)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, P(*fixed))
    )


def named_sharding(logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, resolve(logical))


def sharding_for(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
