"""Parameter / cache / batch partitioning rules for the production mesh.

Path-based rules with divisibility fallback: every dim annotated with a mesh
axis must divide evenly, otherwise that dim falls back to replication (e.g.
mamba2's vocab 50280 is not 16-divisible -> embed replicated; production
would pad the vocab, we keep the published config exact and note it).

Strategy (DESIGN.md §5): DP on ("pod","data") for batch dims; TP on "model"
for head/ff/expert/vocab dims; the KV cache shards its *sequence* dim on
"model" (SPMD flash-decode: GSPMD turns softmax over the sharded dim into
partial-softmax + tiny all-reduce); SP on the residual stream for training.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...] = ("pod", "data")   # batch axes
    tp: str = "model"

    def present(self, mesh: Mesh) -> "MeshAxes":
        names = set(mesh.axis_names)
        return MeshAxes(dp=tuple(a for a in self.dp if a in names),
                        tp=self.tp if self.tp in names else "")


def _axis_size(mesh: Mesh, axis) -> int:
    if not axis:
        return 1
    if isinstance(axis, str):
        return mesh.shape.get(axis, 1) if hasattr(mesh.shape, "get") \
            else dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1)
    return int(np.prod([_axis_size(mesh, a) for a in axis]))


def _present(mesh: Mesh, axis):
    names = set(mesh.axis_names)
    if isinstance(axis, str):
        return axis if axis in names else None
    kept = tuple(a for a in axis if a in names)
    return kept if kept else None


def _fit(mesh: Mesh, dim: int, axis) -> Optional[Any]:
    """axis (restricted to present mesh axes) if dim divides evenly."""
    axis = _present(mesh, axis) if axis else None
    if axis is None:
        return None
    n = _axis_size(mesh, axis)
    return axis if (n > 1 and dim % n == 0) else None


def _leaf_spec(path: str, shape: Tuple[int, ...], mesh: Mesh, ax: MeshAxes,
               stacked: bool, tied: bool = False) -> P:
    """PartitionSpec for a parameter leaf identified by its tree path."""
    tp = ax.tp
    dims: list = [None] * len(shape)
    body = shape[1:] if stacked else shape
    off = 1 if stacked else 0

    def col(i):   # shard output/column dim
        dims[off + i] = _fit(mesh, body[i], tp)

    name = path.split("/")[-1]
    if name == "embed":
        if tied:
            col(0)           # (V, d): vocab-sharded (serves logits too)
        else:
            col(1)           # d-sharded: token lookup stays gather-local
            # (a vocab-sharded table makes GSPMD all-gather it per step)
    elif name == "unembed":
        col(0)                                     # (V, d): vocab (logits)
    elif name in ("wq", "wk", "wv", "wq_b", "wkv_b", "in_y", "in_x",
                  "in_proj"):
        col(len(body) - 1)                         # (d, out): out dim
    elif name in ("wo", "out_proj"):
        col(0)                                     # (in, d): in dim
    elif name in ("gate", "up", "down") and len(body) == 3:
        # MoE expert weights: (E, d, ff) or (E, ff, d). Expert-parallel over
        # as many axes as E divides; remaining axes shard the ff dim so the
        # footprint always spreads over the whole mesh (deepseek-v3: EP=256;
        # mixtral: E=8 -> d/ff 2D sharding).
        ff_i = 2 if name in ("gate", "up") else 1
        d_i = 1 if name in ("gate", "up") else 2
        full_ep = _fit(mesh, body[0], ("data", "model"))
        if full_ep is not None:
            dims[off + 0] = full_ep
        elif _fit(mesh, body[0], tp) is not None:
            dims[off + 0] = tp
            dims[off + ff_i] = _fit(mesh, body[ff_i], "data")
        else:
            dims[off + ff_i] = _fit(mesh, body[ff_i], tp)
            dims[off + d_i] = _fit(mesh, body[d_i], "data")
    elif name in ("gate", "up"):
        col(1)                                     # (d, ff)
    elif name == "down":
        col(0)                                     # (ff, d)
    elif name == "router":
        col(len(body) - 1)                         # (d, E)
    elif name in ("conv_w",):
        col(len(body) - 1)                         # (w, channels)
    elif name in ("conv_b", "gate_norm", "lamb"):
        col(0) if len(body) == 1 and body[0] >= 128 else None
    elif name in ("gate_a", "gate_x"):
        col(0)                                     # (nb, bs, bs): blocks
    elif name == "proj":                           # mtp (2d, d)
        col(1)
    elif name in ("wq_a", "wkv_a"):
        col(len(body) - 1)
    # everything else (norms, A_log, dt_bias, D, q_norm, ...) replicated
    return P(*dims)


def _walk(tree, fn, path=""):
    if isinstance(tree, dict):
        return {k: _walk(v, fn, f"{path}/{k}" if path else k)
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [_walk(v, fn, f"{path}/{i}") for i, v in enumerate(tree)]
        return type(tree)(out) if isinstance(tree, tuple) else out
    return fn(path, tree)


def param_specs(cfg: ModelConfig, params, mesh: Mesh,
                axes: MeshAxes = MeshAxes()):
    """PartitionSpec tree mirroring init_params output."""
    ax = axes.present(mesh)

    def spec(path, leaf):
        stacked = ("scan" in path.split("/")) and leaf.ndim >= 1
        return _leaf_spec(path, leaf.shape, mesh, ax, stacked,
                          tied=cfg.tie_embeddings)

    return _walk(params, spec)


def fsdp_param_specs(cfg: ModelConfig, params, mesh: Mesh):
    """Fully-sharded weights: every leaf sharded on its largest divisible
    dim over the flattened mesh (then progressively fewer axes). GSPMD
    all-gathers each layer's shard on use — classic FSDP/ZeRO-3."""
    axis_opts = [("pod", "data", "model"), ("data", "model"),
                 ("model",), ("data",)]

    def spec(path, leaf):
        stacked = ("scan" in path.split("/")) and leaf.ndim >= 2
        off = 1 if stacked else 0
        body = leaf.shape[off:]
        dims = [None] * leaf.ndim
        if not body:
            return P(*dims)
        # largest dim first
        order = sorted(range(len(body)), key=lambda i: -body[i])
        for ax in axis_opts:
            fit = next((i for i in order
                        if _fit(mesh, body[i], ax) is not None), None)
            if fit is not None:
                dims[off + fit] = _fit(mesh, body[fit], ax)
                return P(*dims)
        return P(*dims)

    return _walk(params, spec)


def adapter_specs(cfg: ModelConfig, adapters, mesh: Mesh,
                  axes: MeshAxes = MeshAxes()):
    """LoRA adapters are tiny: replicate everything (their grads cross pods
    cheaply — the point of PEFT co-location)."""
    return jax.tree.map(lambda leaf: P(), adapters)


def _cache_leaf_spec(path: str, shape, mesh: Mesh, ax: MeshAxes,
                     stacked: bool) -> P:
    dp, tp = ax.dp, ax.tp
    name = path.split("/")[-1]
    dims: list = [None] * len(shape)
    off = 1 if stacked else 0
    body = shape[off:]
    if not body:
        return P(*dims)
    dims[off] = _fit(mesh, body[0], dp)            # batch dim first
    if name in ("k", "v", "c_kv", "k_rope", "kv_pos", "xk", "xv") \
            and len(body) >= 2:
        dims[off + 1] = _fit(mesh, body[1], tp)    # sequence dim
    elif name == "h" and len(body) >= 2:           # ssm/rg state
        dims[off + 1] = _fit(mesh, body[1], tp)    # heads / width
    elif name == "conv" and len(body) == 3:
        dims[off + 2] = _fit(mesh, body[2], tp)    # channels
    return P(*dims)


def cache_specs(cfg: ModelConfig, cache, mesh: Mesh,
                axes: MeshAxes = MeshAxes()):
    ax = axes.present(mesh)

    def spec(path, leaf):
        stacked = ("scan" in path.split("/"))
        return _cache_leaf_spec(path, leaf.shape, mesh, ax, stacked)

    return _walk(cache, spec)


def batch_specs(batch: Dict[str, Any], mesh: Mesh,
                axes: MeshAxes = MeshAxes()):
    ax = axes.present(mesh)

    def spec(path, leaf):
        dims = [None] * leaf.ndim
        if leaf.ndim >= 1:
            dims[0] = _fit(mesh, leaf.shape[0], ax.dp)
        return P(*dims)

    return _walk(batch, spec)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
