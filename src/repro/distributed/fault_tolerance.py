"""Fault tolerance: sharded checkpoints, elastic resharding, stragglers.

Production posture for 1000+ nodes (DESIGN.md §5):
  * checkpoints are written per-leaf with an atomic manifest commit
    (tmp dir + rename), asynchronously off the training thread; any number
    of retained steps; corruption-safe restore (last committed manifest);
  * restore is *elastic*: arrays are re-laid-out onto whatever mesh the
    restarted job has (``device_put`` with the new NamedSharding) — a pod
    loss degrades to an (N-1)-pod mesh after restore;
  * straggler mitigation at the decode-round granularity: rounds that
    overrun a robust deadline trigger a quantum downgrade (finetune work is
    the shock absorber — never the decode QoS).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# numpy can't natively (de)serialize bf16/f8 — store a byte view and
# reinterpret on restore using the manifest's logical dtype
_EXOTIC = {"bfloat16": ml_dtypes.bfloat16,
           "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
           "float8_e5m2": ml_dtypes.float8_e5m2}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
    return arr


def _from_saved(arr: np.ndarray, dtype_name: str):
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name]).reshape(arr.shape[:-1])
    return arr


# ----------------------------------------------------------- tree <-> flat --
def _flatten(tree, path=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, f"{path}/{k}" if path else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{path}/{i}")
    else:
        yield path, tree


def _unflatten(template, flat: Dict[str, Any], path=""):
    if isinstance(template, dict):
        return {k: _unflatten(v, flat, f"{path}/{k}" if path else str(k))
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        out = [_unflatten(v, flat, f"{path}/{i}")
               for i, v in enumerate(template)]
        return type(template)(out) if isinstance(template, tuple) else out
    return flat[path]


class CheckpointManager:
    """Atomic, async, sharded-restore checkpoint manager."""

    def __init__(self, directory, keep: int = 3):
        if keep < 0:
            raise ValueError(f"keep must be >= 0, got {keep}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree, blocking: bool = True) -> None:
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host_tree),
                daemon=True)
            self._thread.start()

    def _write_guarded(self, step, tree):
        try:
            self._write(step, tree)
        except BaseException as e:   # surfaced on next wait()
            self._error = e

    def _write(self, step: int, tree) -> None:
        tmp = self.dir / f".tmp_step_{step}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "leaves": {}, "time": time.time()}
        for i, (path, leaf) in enumerate(_flatten(tree)):
            fn = f"leaf_{i:05d}.npy"
            arr = np.asarray(leaf)
            np.save(tmp / fn, _to_savable(arr), allow_pickle=False)
            manifest["leaves"][path] = {
                "file": fn, "shape": list(arr.shape),
                "dtype": arr.dtype.name}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                         # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        # keep == 0 retains nothing: steps[:-0] would be the EMPTY slice
        # (retaining everything), so it needs its own branch
        drop = steps if self.keep == 0 else steps[:-self.keep]
        for s in drop:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # ---------------------------------------------------------- restore --
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():        # committed only
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: Optional[int] = None,
                mesh: Optional[Mesh] = None, specs=None):
        """Restore as numpy (mesh=None) or sharded onto `mesh` with `specs`
        (elastic: the mesh may differ from the one that saved)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        spec_flat = dict(_flatten(specs)) if specs is not None else {}
        for path, info in manifest["leaves"].items():
            arr = _from_saved(np.load(d / info["file"]), info["dtype"])
            if mesh is not None:
                spec = spec_flat.get(path, P())
                arr = jax.device_put(arr, NamedSharding(mesh, spec))
            flat[path] = arr
        return _unflatten(template, flat)


def reshard(tree, mesh: Mesh, specs):
    """Elastic re-layout of a live tree onto a (new) mesh."""
    def put(leaf, spec):
        return jax.device_put(np.asarray(leaf), NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, specs,
                        is_leaf=lambda x: not isinstance(x, (dict, list,
                                                             tuple)))


# -------------------------------------------------------------- stragglers --
@dataclasses.dataclass
class StragglerConfig:
    window: int = 64             # rounds in the rolling estimate
    deadline_factor: float = 2.5  # x median = overrun
    cooloff_rounds: int = 8      # quantum suppressed after an overrun


class StragglerMitigator:
    """Decode-round deadline monitor: overruns (preemption, slow host,
    failing chip) shed finetune work first, never inference."""

    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.history: List[float] = []
        self.overruns = 0
        self._cooloff = 0

    def deadline(self) -> float:
        if len(self.history) < 8:
            return float("inf")
        h = sorted(self.history[-self.cfg.window:])
        return h[len(h) // 2] * self.cfg.deadline_factor

    def observe(self, round_s: float,
                expected_s: Optional[float] = None) -> bool:
        """Returns True when the round overran (caller drops quantum).

        With `expected_s` (the cost/predictor estimate for THIS round's
        (bs, k)), the gate is vs expectation — robust to the bimodal round
        distributions that co-location produces (k=0 vs k=k_max rounds
        differ 3x by design and must not look like stragglers). Without it,
        falls back to a rolling-median deadline."""
        if expected_s is not None and expected_s > 0:
            over = round_s > 2.0 * expected_s
        else:
            over = round_s > self.deadline()
        self.history.append(round_s)
        if over:
            self.overruns += 1
            self._cooloff = self.cfg.cooloff_rounds
        elif self._cooloff > 0:
            self._cooloff -= 1
        return over

    @property
    def suppress_quantum(self) -> bool:
        return self._cooloff > 0
