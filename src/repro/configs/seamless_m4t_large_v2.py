"""seamless-m4t-large-v2 — enc-dec, multimodal [arXiv:2308.11596].
24L (dec) + 24L (enc) d_model=1024 16H (kv=16) d_ff=8192 vocab 256206.
Audio frontend is a STUB: input_specs() supplies precomputed frame
embeddings (B, frames, d) for the encoder."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    enc_layers=24, cross_attention=True,
    frontend="audio", frontend_tokens=0,
)
