"""mixtral-8x7b — 8 experts top-2, SWA [arXiv:2401.04088].
32L d_model=4096 32H (GQA kv=8) expert d_ff=14336 vocab 32000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    attn_type="swa", window=4096, rope_theta=1e6,
    moe=True, num_experts=8, top_k=2, moe_d_ff=14336,
)
