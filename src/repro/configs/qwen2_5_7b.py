"""qwen2.5-7b — the paper's second eval model [Harli §8.1].
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab 152064."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, rope_theta=1e6,
)
