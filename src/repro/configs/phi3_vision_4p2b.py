"""phi-3-vision-4.2b — phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct]. 32L d_model=3072 32H (kv=32)
d_ff=8192 vocab 32064. Vision frontend is a STUB: input_specs() supplies
576 precomputed patch embeddings (B, 576, d)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    frontend="vision", frontend_tokens=576,
)
