"""llama3-8b — the paper's primary inference/finetune model [Harli §8.1].
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab 128256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=5e5,
)
