"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8, MTP [arXiv:2412.19437].
61L d_model=7168 128H, MoE d_ff=2048 (dense head layers 18432), vocab 129280."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=18432, vocab_size=129280,
    mla=True, mla_q_rank=1536, mla_kv_rank=512, mla_rope_dim=64,
    mla_nope_dim=128, mla_v_dim=128,
    moe=True, num_experts=256, top_k=8, num_shared_experts=1,
    moe_d_ff=2048, first_dense_layers=3,
    mtp=True, rope_theta=1e4,
)
