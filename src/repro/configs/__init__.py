"""Architecture registry + assigned shape cells.

``--arch <id>`` everywhere resolves through ``get_config``. Each arch also has
a reduced smoke sibling (``smoke_config``) exercised by tests; full configs
are only lowered symbolically by the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Iterator, Optional, Tuple

from repro.models.config import ModelConfig, scale_down

_MODULES = {
    "mamba2-780m": "mamba2_780m",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-14b": "qwen3_14b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "qwen3-8b": "qwen3_8b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    # the paper's own evaluation models
    "llama3-8b": "llama3_8b",
    "qwen2.5-7b": "qwen2_5_7b",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k not in
                       ("llama3-8b", "qwen2.5-7b"))
PAPER_ARCHS = ("llama3-8b", "qwen2.5-7b")


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    return scale_down(get_config(name))


# ------------------------------------------------------------ shape cells --
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic / bounded-cache decode (DESIGN.md §4):
# SSM and hybrid run it; SWA archs run it (decode cost O(window)); pure
# full-attention archs skip it.
_SUBQUADRATIC = ("mamba2-780m", "recurrentgemma-2b", "mixtral-8x7b",
                 "h2o-danube-1.8b")


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in _SUBQUADRATIC:
        return False, "pure full-attention decode (sub-quadratic required)"
    return True, ""


def cells(include_skipped: bool = False
          ) -> Iterator[Tuple[str, str, Optional[str]]]:
    """Yield (arch, shape, skip_reason|None) over the assigned 40-cell grid."""
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            ok, why = cell_supported(arch, shape)
            if ok:
                yield arch, shape, None
            elif include_skipped:
                yield arch, shape, why
