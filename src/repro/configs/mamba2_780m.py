"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].
48L d_model=1536, attention-free, ssm_state=128, vocab 50280."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True,
)
