"""Paged GQA flash-decode attention — the decode-phase hot-spot kernel.

TPU-native design (DESIGN.md §6): the KV cache lives in a paged pool; the
page table is scalar-prefetched into SMEM so each grid step's BlockSpec
index_map dereferences the *physical* page — the kernel never gathers pages
through HBM-to-HBM copies (the GPU paged-attention trick mapped onto Pallas'
prefetch mechanism). Online-softmax accumulation runs in VMEM scratch across
the page-grid dimension; q-heads of one KV head (GQA group) are processed
together so the MXU sees a (g x page_tokens) matmul per step.

Layout:
  q           (B, H, hd)
  k/v pages   (P, ptok, KV, hd)      one layer's pool
  page_table  (B, n_pages) int32     physical page per logical block
  lengths     (B,) int32             tokens valid per sequence
Grid: (B, KV, n_pages) — page dim innermost, scratch carries (m, l, acc).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table, lengths, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, ptok: int, scale: float):
    b = pl.program_id(0)
    kv = pl.program_id(1)
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths[b]
    page_valid = page_table[b, p] >= 0

    @pl.when(page_valid)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)         # (g, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)      # (ptok, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)      # (ptok, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (g, ptok)
        pos = p * ptok + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        e = jnp.exp(s - m_new)
        e = jnp.where(pos < length, e, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(e, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            e, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           scale=None, interpret: bool = True):
    """q: (B, H, hd); k/v_pages: (P, ptok, KV, hd); page_table: (B, n_pages);
    lengths: (B,). Returns (B, H, hd)."""
    B, H, hd = q.shape
    P, ptok, KV, _ = k_pages.shape
    n_pages = page_table.shape[1]
    g = H // KV
    scale = scale if scale is not None else hd ** -0.5
    qr = q.reshape(B, KV, g, hd)

    grid = (B, KV, n_pages)

    def q_map(b, kv, p, pt, ln):
        return (b, kv, 0, 0)

    def kv_map(b, kv, p, pt, ln):
        page = jnp.maximum(pt[b, p], 0)
        return (page, 0, kv, 0)

    def o_map(b, kv, p, pt, ln):
        return (b, kv, 0, 0)

    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), q_map),
            pl.BlockSpec((1, ptok, 1, hd), kv_map),
            pl.BlockSpec((1, ptok, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), o_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ptok=ptok, scale=scale),
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qr, k_pages, v_pages)
    return out.reshape(B, H, hd)
