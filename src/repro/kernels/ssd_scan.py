"""Mamba2 SSD chunked scan kernel (intra-chunk quadratic + carried state).

The SSD recurrence is re-blocked for the TPU memory hierarchy: the grid walks
(batch, head-block, chunk) with the chunk dimension innermost; the running
state h (nhb, hd, ds) lives in VMEM scratch across chunk steps, so HBM sees
each token exactly once (the recurrent analogue of flash attention). The
intra-chunk quadratic form (c x c) is MXU work; chunk size 64 keeps the
decay tensor inside VMEM at fp32.

Inputs (pre-chunked, dt already softplus'ed):
  xs (B, n, c, nh, hd)   dt (B, n, c, nh)   A (nh,)
  Bt (B, n, c, ds)       Ct (B, n, c, ds)   h0 (B, nh, hd, ds)
Outputs: y (B, n, c, nh, hd) fp32, hT (B, nh, hd, ds) fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xs_ref, dt_ref, a_ref, bt_ref, ct_ref, h0_ref,
            y_ref, ht_ref, h_ref):
    n = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(n == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    xs = xs_ref[0, 0].astype(jnp.float32)       # (c, nhb, hd)
    dt = dt_ref[0, 0].astype(jnp.float32)       # (c, nhb)
    A = a_ref[0].astype(jnp.float32)            # (nhb,)
    Bt = bt_ref[0, 0].astype(jnp.float32)       # (c, ds)
    Ct = ct_ref[0, 0].astype(jnp.float32)       # (c, ds)
    c = xs.shape[0]

    la = dt * A[None, :]                        # (c, nhb) log-decay
    cum = jnp.cumsum(la, axis=0)

    # intra-chunk quadratic form
    scores = jax.lax.dot_general(Ct, Bt, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (c, c)
    decay = cum[:, None, :] - cum[None, :, :]                  # (c, c, nhb)
    tril = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    m = jnp.where(tril[:, :, None], scores[:, :, None] * jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("cmh,mh,mhp->chp", m, dt, xs)

    # inter-chunk contribution from the carried state
    h = h_ref[...]                                             # (nhb, hd, ds)
    y_inter = jnp.einsum("cs,hps,ch->chp", Ct, h, jnp.exp(cum))

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(cum_end) * h + sum_j exp(cum_end - cum_j) dt x B
    dec_end = jnp.exp(cum[-1][None, :] - cum)                  # (c, nhb)
    h_new = jnp.exp(cum[-1])[:, None, None] * h + jnp.einsum(
        "ch,ch,chp,cs->hps", dec_end, dt, xs, Bt)
    h_ref[...] = h_new

    @pl.when(n == n_chunks - 1)
    def _fin():
        ht_ref[0] = h_new.astype(ht_ref.dtype)


def ssd_scan_chunked(xs, dt, A, Bt, Ct, h0, *, nhb: int = 8,
                     interpret: bool = True):
    """Pre-chunked SSD scan. Shapes per module docstring."""
    B, n, c, nh, hd = xs.shape
    ds = Bt.shape[-1]
    nhb = min(nhb, nh)
    assert nh % nhb == 0, (nh, nhb)
    hb = nh // nhb
    grid = (B, hb, n)

    y, ht = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, nhb, hd), lambda b, h, n: (b, n, 0, h, 0)),
            pl.BlockSpec((1, 1, c, nhb), lambda b, h, n: (b, n, 0, h)),
            pl.BlockSpec((1, nhb), lambda b, h, n: (0, h)),
            pl.BlockSpec((1, 1, c, ds), lambda b, h, n: (b, n, 0, 0)),
            pl.BlockSpec((1, 1, c, ds), lambda b, h, n: (b, n, 0, 0)),
            pl.BlockSpec((1, nhb, hd, ds), lambda b, h, n: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, nhb, hd), lambda b, h, n: (b, n, 0, h, 0)),
            pl.BlockSpec((1, nhb, hd, ds), lambda b, h, n: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n, c, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nhb, hd, ds), jnp.float32)],
        interpret=interpret,
    )(xs, dt, A.reshape(1, nh), Bt, Ct, h0)
    return y, ht
