"""Fused LoRA matmul: y = x @ W + s * (x @ A) @ B — the finetune hot-spot.

The rank-r intermediate xa never round-trips through HBM: it is computed on
the first n-block of each m-row and kept in VMEM scratch while the row's
output tiles stream through the MXU. Tiles are 128-aligned for the systolic
array; K is looped inside the kernel via the grid's innermost dimension with
a float32 accumulator in scratch.

Grid: (M/bm, N/bn, K/bk) — k innermost (accumulation), n middle, m outer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
            scale: float, n_k: int):
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    acc_ref[...] += jax.lax.dot(
        x, w_ref[...], preferred_element_type=jnp.float32)

    # accumulate xa = x @ A on the first n-block only (same for all n)
    @pl.when(n == 0)
    def _xa():
        @pl.when(k == 0)
        def _z():
            xa_ref[...] = jnp.zeros_like(xa_ref)
        xa_ref[...] += jax.lax.dot(
            x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _fin():
        y = acc_ref[...] + scale * jax.lax.dot(
            xa_ref[...].astype(b_ref.dtype), b_ref[...],
            preferred_element_type=jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


def lora_matmul(x, w, a, b, scale: float, *, block_m: int = 128,
                block_n: int = 128, block_k: int = 512,
                interpret: bool = True):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N). Returns (M, N)."""
    M, K = x.shape
    _, N = w.shape
    r = a.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"shapes must tile: {(M, N, K)} by {(bm, bn, bk)}"
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bk, r), lambda m, n, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b)
    return out
