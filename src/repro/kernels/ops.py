"""Jit'd public wrappers for the Pallas kernels.

TPU is the target; on any other backend the kernels execute in interpret
mode (Python evaluation of the kernel body) so correctness is validated
everywhere. Wrappers own the layout plumbing: padding to tile multiples,
(B, S, d) <-> (M, K) reshapes, and the dense-cache adapter used by
model.decode_step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import lora_matmul as _lm
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------- decode attention --
@functools.partial(jax.jit, static_argnames=("scale",))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths,
                           scale=None):
    return _da.paged_decode_attention(q, k_pages, v_pages, page_table,
                                      lengths, scale=scale,
                                      interpret=_interpret())


def decode_attention(q, kc, vc, kv_pos, positions, window: int = 0,
                     scale=None, page_tokens: int = 64, scales=None):
    """Dense-cache adapter matching attention.decode_attn_ref's signature so
    model.decode_step can swap the kernel in: treats each slot's contiguous
    cache as pages of `page_tokens`.

    q: (B, H, hd); kc/vc: (B, S, KV, hd); kv_pos: (B, S); positions: (B,).
    """
    B, S, KV, hd = kc.shape
    if window > 0 or S % page_tokens:
        # ring-buffered (SWA) caches keep arbitrary positions per slot —
        # fall back to the reference path (kernel targets the paged pool).
        from repro.models.attention import decode_attn_ref
        return decode_attn_ref(q, kc, vc, kv_pos, positions, window,
                               scale=scale)
    n_pages = S // page_tokens
    k_pages = kc.reshape(B * n_pages, page_tokens, KV, hd)
    v_pages = vc.reshape(B * n_pages, page_tokens, KV, hd)
    page_table = jnp.arange(B * n_pages, dtype=jnp.int32).reshape(B, n_pages)
    lengths = positions + 1
    return _da.paged_decode_attention(q, k_pages, v_pages, page_table,
                                      lengths, scale=scale,
                                      interpret=_interpret())


# ------------------------------------------------------------ LoRA matmul --
def lora_matmul(x, w, a, b, scale: float, block_m: int = 128,
                block_n: int = 128, block_k: int = 512):
    """x: (..., K); w: (K, N); a: (K, r); b: (r, N) -> (..., N).
    Pads M/N/K to tile multiples; r stays as-is (kept in VMEM)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    xm = x.reshape(-1, K)
    M = xm.shape[0]
    bm = min(block_m, max(M, 8))
    bn = min(block_n, N)
    bk = min(block_k, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        xm = jnp.pad(xm, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn))) if (pk or pn) else w
    ap = jnp.pad(a, ((0, pk), (0, 0))) if pk else a
    bp = jnp.pad(b, ((0, 0), (0, pn))) if pn else b
    y = _lm.lora_matmul(xm, wp, ap, bp, scale, block_m=bm, block_n=bn,
                        block_k=bk, interpret=_interpret())
    return y[:M, :N].reshape(*lead, N)


# ---------------------------------------------------------------- SSD scan --
def ssd_scan(xs, dt, A, Bt, Ct, chunk: int, h0=None, nhb: int = 8):
    """Chunked SSD scan matching models.ssm.ssd_chunked's contract.
    xs: (B, S, nh, hd); dt: (B, S, nh) (softplus applied); A: (nh,) (<0);
    Bt/Ct: (B, S, ds). Returns y (B, S, nh, hd) f32, hT (B, nh, hd, ds) f32.
    """
    B, S, nh, hd = xs.shape
    ds = Bt.shape[-1]
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, pad), (0, 0)))
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    while nh % nhb:
        nhb //= 2
    y, ht = _ssd.ssd_scan_chunked(
        xs.reshape(B, n, c, nh, hd), dt.reshape(B, n, c, nh), A,
        Bt.reshape(B, n, c, ds), Ct.reshape(B, n, c, ds), h0,
        nhb=max(nhb, 1), interpret=_interpret())
    return y.reshape(B, n * c, nh, hd)[:, :S], ht
