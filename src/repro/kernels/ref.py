"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths,
                               scale=None):
    """Dense gather + softmax oracle. Shapes as in decode_attention."""
    B, H, hd = q.shape
    P, ptok, KV, _ = k_pages.shape
    n_pages = page_table.shape[1]
    g = H // KV
    scale = scale if scale is not None else hd ** -0.5
    pt = jnp.maximum(page_table, 0)
    k = k_pages[pt].reshape(B, n_pages * ptok, KV, hd)
    v = v_pages[pt].reshape(B, n_pages * ptok, KV, hd)
    pos = jnp.arange(n_pages * ptok)[None, :]
    valid = (pos < lengths[:, None]) & \
        jnp.repeat(page_table >= 0, ptok, axis=1)
    qr = q.reshape(B, KV, g, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isneginf(m), 0.0, m)
    e = jnp.exp(s - m)
    e = jnp.where(valid[:, None, None, :], e, 0.0)
    o = jnp.einsum("bkgs,bskh->bkgh", e, v.astype(jnp.float32))
    o = o / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    return o.reshape(B, H, hd).astype(q.dtype)


def lora_matmul_ref(x, w, a, b, scale):
    """y = x @ w + scale * (x @ a) @ b. x: (M, K), w: (K, N), a: (K, r),
    b: (r, N)."""
    y = jnp.einsum("mk,kn->mn", x.astype(jnp.float32), w.astype(jnp.float32))
    xa = jnp.einsum("mk,kr->mr", x.astype(jnp.float32), a.astype(jnp.float32))
    y = y + scale * jnp.einsum("mr,rn->mn", xa, b.astype(jnp.float32))
    return y.astype(x.dtype)


def ssd_scan_ref(xs, dt, A, Bt, Ct, chunk, h0=None):
    """Chunked SSD oracle — delegates to the model's reference implementation
    (itself validated against a sequential recurrence in tests)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(xs, dt, A, Bt, Ct, chunk, h0=h0)


def ssd_sequential_ref(xs, dt, A, Bt, Ct, h0=None):
    """O(S) sequential recurrence — ground truth for the chunked forms."""
    B, S, nh, hd = xs.shape
    ds = Bt.shape[-1]
    h = jnp.zeros((B, nh, hd, ds), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    xs = xs.astype(jnp.float32)
    Bt = Bt.astype(jnp.float32)
    Ct = Ct.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        a_t = jnp.exp(dt_t * A[None, :])                    # (B, nh)
        h = a_t[:, :, None, None] * h + jnp.einsum(
            "bh,bhp,bs->bhps", dt_t, x_t, b_t)
        y = jnp.einsum("bs,bhps->bhp", c_t, h)
        return h, y

    hT, ys = jax.lax.scan(
        step, h,
        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(Bt, 1, 0), jnp.moveaxis(Ct, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), hT
