"""PEFT (LoRA) finetune engine with *layer-wise scheduling units* (paper §6.1).

The paper splits each finetune iteration into per-layer forward/backward
submodels so the scheduler can interleave ~10 ms units between decode tokens.
PyTorch needed explicit submodel surgery for this; in JAX we express the whole
iteration as a state machine whose ``unit_step`` executes exactly one unit via
``lax.switch`` — every unit has the same state signature, so a colocated
program can run ``k`` units per decode round with ``k`` chosen by the
scheduler (core/colocation.py).

Unit sequence for one iteration (accum microbatches, L scanned layers):
  per microbatch: EMBED(+pre fwd) | L x FWD(layer i) | HEAD(loss, post bwd)
                  | L x BWD(layer j) | EMBED_BWD(pre bwd + data advance)
  then:           OPT (AdamW on accumulated adapter grads)

Backward units recompute their layer's forward from the saved layer-input
residual under ``jax.vjp`` (layer-granular activation checkpointing — the
JAX-idiomatic equivalent of the paper's "retain activations in GPU memory",
chosen because it also bounds the co-located memory footprint, §4.3).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import lora as LR
from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class PeftConfig:
    micro_batch: int = 2          # paper §8.2: micro-batched to bs=2
    seq_len: int = 1024
    accum: int = 8                # minibatch 16 = 8 x 2 (paper baseline bs)
    n_stage: int = 2              # host-staged microbatch ring depth
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


# ===================================================== full train step ====
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    use_kernels: bool = False, remat: bool = True):
    """One-shot PEFT train step (grads wrt adapters only) — the ``train_4k``
    dry-run cell and the standalone finetune driver use this."""

    def train_step(params, adapters, opt_state, batch):
        def loss_of(ad):
            loss, metrics = MD.loss_fn(params, cfg, batch, adapters=ad,
                                       use_kernels=use_kernels, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(adapters)
        new_adapters, new_opt = adamw_update(opt_cfg, grads, opt_state,
                                             adapters)
        metrics = dict(metrics, loss=loss)
        return new_adapters, new_opt, metrics

    return train_step


# ===================================================== layer-unit engine ==
def n_units_per_mb(cfg: ModelConfig) -> int:
    _, _, n_scan, _ = MD._plan(cfg)
    return 2 * n_scan + 3


def units_per_iteration(cfg: ModelConfig, accum: int) -> int:
    return accum * n_units_per_mb(cfg) + 1


def init_ft_state(cfg: ModelConfig, pc: PeftConfig, params, key,
                  staged: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
    """staged: {"tokens": (n_stage, B, S), "labels": ...} from data.Prefetcher."""
    _, _, n_scan, _ = MD._plan(cfg)
    B, S, d = pc.micro_batch, pc.seq_len, cfg.d_model
    adapters = MD.init_adapters(cfg, key)
    zeros_like_f32 = lambda t: jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32), t)
    state = {
        "adapters": adapters,
        "opt": adamw_init(adapters),
        "grads": zeros_like_f32(adapters),
        "x": jnp.zeros((B, S, d), jnp.bfloat16),
        "residuals": jnp.zeros((n_scan + 1, B, S, d), jnp.bfloat16),
        "data": {k: jnp.asarray(v) for k, v in staged.items()},
        "data_idx": jnp.zeros((), jnp.int32),
        "unit_idx": jnp.zeros((), jnp.int32),
        "loss": jnp.zeros((), jnp.float32),
        "last_loss": jnp.zeros((), jnp.float32),
        "iter": jnp.zeros((), jnp.int32),
        "consumed": jnp.zeros((), jnp.int32),
    }
    if cfg.enc_layers:
        se = staged["enc_frames"].shape[2]
        state["enc_out"] = jnp.zeros((B, se, d), jnp.bfloat16)
    return state


def make_unit_step(cfg: ModelConfig, pc: PeftConfig, params):
    """Build ``unit_step(state) -> state`` executing exactly one unit."""
    pre_kinds, scan_kind, n_scan, post_kinds = MD._plan(cfg)
    scale = LR.lora_scale(cfg)
    upm = n_units_per_mb(cfg)
    total_units = units_per_iteration(cfg, pc.accum)

    def positions(B, S):
        return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def current_batch(state):
        idx = state["data_idx"] % pc.n_stage
        return {k: v[idx] for k, v in state["data"].items()}

    # ---------------- front stack (embed + pre layers [+ encoder]) -------
    def front(state, pre_ads):
        batch = current_batch(state)
        b = dict(tokens=batch["tokens"])
        if "frontend" in batch:
            b["frontend"] = batch["frontend"]
        x, pos, off = MD._embed_inputs(params, cfg, b)
        enc_out = None
        if cfg.enc_layers:
            enc_out = MD._encode(params, cfg,
                                 {"enc_frames": batch["enc_frames"]})
        for i, kd in enumerate(pre_kinds):
            ad = LR.as_pairs(pre_ads[i]) if pre_ads else None
            x, _, _ = MD.apply_layer(params["pre"][i], x, pos, cfg, kd,
                                     mode="full", lora=ad, scale=scale,
                                     enc_out=enc_out)
        return x, pos, enc_out

    def u_embed(state):
        pre_ads = state["adapters"]["pre"] if pre_kinds else None
        x, _, enc_out = front(state, pre_ads)
        state = dict(state)
        state["x"] = x.astype(jnp.bfloat16)
        state["residuals"] = state["residuals"].at[0].set(
            x.astype(jnp.bfloat16))
        if cfg.enc_layers and enc_out is not None:
            state["enc_out"] = enc_out.astype(jnp.bfloat16)
        return state

    # ---------------- one scanned layer, fwd ------------------------------
    def layer_fwd(x, i, ad_scan, state):
        lp = jax.tree.map(lambda t: t[i], params["scan"])
        ad = LR.as_pairs(jax.tree.map(lambda t: t[i], ad_scan))
        pos = positions(*x.shape[:2])
        enc_out = state.get("enc_out")
        y, _, _ = MD.apply_layer(lp, x, pos, cfg, scan_kind, mode="full",
                                 lora=ad, scale=scale,
                                 enc_out=None if enc_out is None
                                 else enc_out.astype(x.dtype))
        return y

    def u_fwd(state):
        u = state["unit_idx"] % upm
        i = u - 1
        x = state["x"]
        y = layer_fwd(x, i, state["adapters"]["scan"], state)
        state = dict(state)
        state["x"] = y.astype(jnp.bfloat16)
        state["residuals"] = state["residuals"].at[i + 1].set(
            y.astype(jnp.bfloat16))
        return state

    # ---------------- head: post layers + loss; bwd to x ------------------
    def head_loss(x, post_ads, state):
        batch = current_batch(state)
        pos = positions(*x.shape[:2])
        for i, kd in enumerate(post_kinds):
            ad = LR.as_pairs(post_ads[i]) if post_ads else None
            x, _, _ = MD.apply_layer(params["post"][i], x, pos, cfg, kd,
                                     mode="full", lora=ad, scale=scale)
        h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        labels = batch["labels"]
        mask = batch.get("mask")
        return L.chunked_softmax_xent(
            h[:, :-1], table, labels[:, 1:],
            None if mask is None else mask[:, 1:])

    def u_head(state):
        x = state["x"]
        post_ads = state["adapters"]["post"] if post_kinds else None

        if post_kinds:
            (loss), vjp = jax.vjp(
                lambda xx, aa: head_loss(xx, aa, state), x, post_ads)
            dx, dpost = vjp(jnp.ones((), loss.dtype))
            new_grads = list(state["grads"]["post"])
            for i in range(len(post_kinds)):
                new_grads[i] = jax.tree.map(
                    lambda g, d: g + d.astype(jnp.float32),
                    state["grads"]["post"][i], dpost[i])
            grads = dict(state["grads"], post=new_grads)
        else:
            loss, vjp = jax.vjp(lambda xx: head_loss(xx, None, state), x)
            (dx,) = vjp(jnp.ones((), loss.dtype))
            grads = state["grads"]
        state = dict(state, grads=grads)
        state["x"] = dx.astype(jnp.bfloat16)
        state["loss"] = state["loss"] + loss / pc.accum
        return state

    # ---------------- one scanned layer, bwd ------------------------------
    def u_bwd(state):
        u = state["unit_idx"] % upm
        i = 2 * n_scan + 1 - u                    # layer index, descending
        x_in = state["residuals"][i]
        dy = state["x"]
        ad_i = jax.tree.map(lambda t: t[i], state["adapters"]["scan"])

        def f(xx, aa):
            lp = jax.tree.map(lambda t: t[i], params["scan"])
            pos = positions(*xx.shape[:2])
            enc_out = state.get("enc_out")
            y, _, _ = MD.apply_layer(lp, xx, pos, cfg, scan_kind, mode="full",
                                     lora=LR.as_pairs(aa), scale=scale,
                                     enc_out=None if enc_out is None
                                     else enc_out.astype(xx.dtype))
            return y

        _, vjp = jax.vjp(f, x_in, ad_i)
        dx, dad = vjp(dy.astype(jnp.bfloat16))
        grads_scan = jax.tree.map(
            lambda g, d: g.at[i].add(d.astype(jnp.float32)),
            state["grads"]["scan"], dad)
        state = dict(state, grads=dict(state["grads"], scan=grads_scan))
        state["x"] = dx.astype(jnp.bfloat16)
        return state

    # ---------------- pre-stack bwd + microbatch bookkeeping --------------
    def u_embed_bwd(state):
        state = dict(state)
        if pre_kinds:
            dy = state["x"]

            def f(pre_ads):
                x, _, _ = front(state, pre_ads)
                return x

            _, vjp = jax.vjp(f, state["adapters"]["pre"])
            (dpre,) = vjp(dy.astype(jnp.bfloat16))
            new_grads = [jax.tree.map(lambda g, d: g + d.astype(jnp.float32),
                                      state["grads"]["pre"][i], dpre[i])
                         for i in range(len(pre_kinds))]
            state["grads"] = dict(state["grads"], pre=new_grads)
        state["data_idx"] = state["data_idx"] + 1
        state["consumed"] = state["consumed"] + 1
        return state

    # ---------------- optimizer ------------------------------------------
    def u_opt(state):
        new_ad, new_opt = adamw_update(pc.opt, state["grads"], state["opt"],
                                       state["adapters"])
        state = dict(state)
        state["adapters"] = new_ad
        state["opt"] = new_opt
        state["grads"] = jax.tree.map(
            lambda g: jnp.zeros_like(g), state["grads"])
        state["last_loss"] = state["loss"]
        state["loss"] = jnp.zeros((), jnp.float32)
        state["iter"] = state["iter"] + 1
        return state

    branches = [u_embed, u_fwd, u_head, u_bwd, u_embed_bwd, u_opt]

    def branch_id(unit_idx):
        u = unit_idx % upm
        is_opt = unit_idx >= pc.accum * upm
        b = jnp.where(u == 0, 0,
            jnp.where(u <= n_scan, 1,
            jnp.where(u == n_scan + 1, 2,
            jnp.where(u <= 2 * n_scan + 1, 3, 4))))
        return jnp.where(is_opt, 5, b).astype(jnp.int32)

    def unit_step(state):
        b = branch_id(state["unit_idx"])
        state = jax.lax.switch(b, branches, state)
        state["unit_idx"] = (state["unit_idx"] + 1) % total_units
        return state

    return unit_step


def run_units(unit_step, state, k: int):
    """Run k units (k static — compiled per quantum level)."""
    if k <= 0:
        return state
    def body(s, _):
        return unit_step(s), None
    state, _ = jax.lax.scan(body, state, None, length=k)
    return state
