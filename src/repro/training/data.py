"""Synthetic token data pipeline: Zipf-corpus generation + sequence packing.

Double-buffered host staging (``Prefetcher``) mirrors a production input
pipeline: the PEFT engine consumes microbatches from a ring that is refilled
outside jit between scheduling units.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    zipf_a: float = 1.2
    doc_len_mean: int = 512
    seed: int = 0
    frontend_tokens: int = 0       # VLM stub patches per sample
    enc_frames: int = 0            # audio stub frames per sample
    d_model: int = 0


class SyntheticCorpus:
    """Zipf-distributed token documents packed to fixed-length sequences."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

    def _doc(self) -> np.ndarray:
        n = max(int(self.rng.exponential(self.cfg.doc_len_mean)), 8)
        toks = self.rng.zipf(self.cfg.zipf_a, size=n)
        return np.minimum(toks, self.cfg.vocab_size - 1).astype(np.int32)

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        cfg = self.cfg
        buf = np.empty((0,), np.int32)
        while True:
            need = cfg.batch_size * (cfg.seq_len + 1)
            while buf.size < need:
                buf = np.concatenate([buf, self._doc(),
                                      np.array([0], np.int32)])  # doc sep
            chunk = buf[:need].reshape(cfg.batch_size, cfg.seq_len + 1)
            buf = buf[need:]
            # loss_fn shifts internally: CE(logits[:, :-1], labels[:, 1:]),
            # so labels == tokens is the standard next-token setup.
            batch = {"tokens": chunk[:, :-1].copy(),
                     "labels": chunk[:, :-1].copy(),
                     "mask": np.ones((cfg.batch_size, cfg.seq_len),
                                     np.float32)}
            if cfg.frontend_tokens and cfg.d_model:
                batch["frontend"] = self.rng.normal(
                    size=(cfg.batch_size, cfg.frontend_tokens, cfg.d_model)
                ).astype(np.float32)
            if cfg.enc_frames and cfg.d_model:
                batch["enc_frames"] = self.rng.normal(
                    size=(cfg.batch_size, cfg.enc_frames, cfg.d_model)
                ).astype(np.float32)
            yield batch


class Prefetcher:
    """Ring of pre-staged microbatches (the engine's host->device pipeline)."""

    def __init__(self, it: Iterator[Dict[str, np.ndarray]], depth: int = 2):
        self.it = it
        self.depth = depth
        self.ring = [next(it) for _ in range(depth)]
        self.head = 0

    def refill(self, consumed: int) -> None:
        for _ in range(consumed):
            self.ring[self.head] = next(self.it)
            self.head = (self.head + 1) % self.depth

    def stacked(self) -> Dict[str, np.ndarray]:
        """(depth, B, ...) arrays for embedding into the jitted unit state."""
        keys = self.ring[0].keys()
        return {k: np.stack([r[k] for r in self.ring]) for k in keys}
