"""AdamW on pytrees (adapter-only in the PEFT setting), plus schedules.

No optax dependency — the optimizer state must embed inside the layer-unit
state machine (core/colocation.py), so it is a plain pytree with pure
functional updates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 10


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}


def lr_at(cfg: AdamWConfig, t) -> jax.Array:
    t = t.astype(jnp.float32)
    warm = jnp.minimum(t / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves) + 1e-12)


def adamw_update(cfg: AdamWConfig, grads, state, params
                 ) -> Tuple[Any, Dict[str, Any]]:
    t = state["t"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)
    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g,
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g,
                     state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** tf
    bc2 = 1 - cfg.b2 ** tf
    lr = lr_at(cfg, t)

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        if cfg.weight_decay:
            step = step + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}
