"""Request and batch bookkeeping for the disaggregated serving engine."""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple

# Segment-id namespaces for Request.prefix_segments. The ids only need to
# be collision-free across namespaces; bases live here (not in
# core/prefix_tree.py) because serving must not import core.
GROUP_SEG_BASE = 1_000_000_000      # shared system-prompt / template groups
SESSION_SEG_BASE = 2_000_000_000    # per-session prompt remainders


class Phase(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float                 # seconds since trace start
    prompt_len: int
    max_new_tokens: int
    # sticky-routing key (-1 = sessionless): requests sharing a session
    # benefit from prefix-cache reuse when routed to the same instance
    session_id: int = -1
    # symbolic prompt structure for cross-session prefix sharing
    # (core/prefix_tree.py): ordered (segment_id, n_tokens) runs summing
    # to prompt_len. Empty = opaque prompt, cached session-keyed only.
    # Survives reset_for_retry — it is prompt identity, not placement
    # state.
    prefix_segments: Tuple[Tuple[int, int], ...] = ()
    # tokens of the prompt already resident in the target instance's prefix
    # cache (core/prefix_cache.py): they need no prefill compute
    cache_hit_tokens: int = 0
    # chunked-prefill progress (prefill_mode="chunked"): effective prompt
    # tokens already processed in decode-round chunks
    prefilled_tokens: int = 0
    phase: Phase = Phase.QUEUED
    slot: int = -1                 # decode slot index (-1 = unassigned)
    generated: int = 0
    prefill_start: float = -1.0    # time a prefill worker picked it up
    prefill_done: float = -1.0     # time prefill finished (TTFT component)
    prefill_worker: int = -1       # pool worker that ran the prefill
    finish: float = -1.0
    # times the request lost its KV to an instance failure and re-entered
    # the router (cluster failure layer, core/cluster.py)
    restarts: int = 0
    # prompt-position tokens whose KV already arrived on the forced
    # destination via live migration (survivability layer): a partial
    # transfer that lost the preemption race re-prefills only the unsent
    # tail. Cleared by reset_for_retry alongside the cache-hit credit.
    migrated_tokens: int = 0
    # admission-control shed count (degradation ladder): each shed re-entry
    # waits a seeded jittered exponential backoff that lands in TTFT
    retries: int = 0
    # multi-LoRA serving (core/adapters.py): the tenant adapter this
    # request must be served with (-1 = base model), and the version the
    # router stamped from the AdapterRegistry at dispatch
    adapter_id: int = -1
    adapter_version: int = 0
    # per-tenant SLO overrides (None = RouterConfig defaults): request_slo
    # scores each tenant's requests against its own targets
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.generated

    @property
    def effective_prompt_len(self) -> int:
        """Prompt tokens that actually need prefill compute: the prefix-cache
        hit is already resident on the target instance, and migrated KV
        (partial or full transfers that beat the preemption deadline) is
        likewise already on the destination. KV accounting still charges
        the full prompt (resident prefixes occupy cache capacity)."""
        return max(self.prompt_len - self.cache_hit_tokens
                   - self.migrated_tokens, 1)

    def tpot_samples(self) -> List[float]:
        """Per-output-token latencies (decode QoS metric)."""
        ts = self.token_times
        return [ts[i] - ts[i - 1] for i in range(1, len(ts))]

    def reset_for_retry(self) -> None:
        """Strip all per-placement prefill state so the request can re-enter
        the router after its instance died: the KV cache (including any
        prefix-cache credit) is gone, so prefill restarts at full length.
        Decode progress bookkeeping (``generated``/``token_times``) is kept
        — already-emitted tokens happened, and the re-prefill gap shows up
        between consecutive token times as the churn TPOT penalty."""
        self.cache_hit_tokens = 0
        self.migrated_tokens = 0
        self.prefilled_tokens = 0
        self.prefill_start = -1.0
        self.prefill_done = -1.0
        self.prefill_worker = -1
        self.phase = Phase.QUEUED
        self.slot = -1
        self.restarts += 1


@dataclasses.dataclass
class DecodeBatch:
    """One decode round over the active slots."""
    requests: List[Request]

    @property
    def bs(self) -> int:
        return len(self.requests)

    @property
    def mean_context(self) -> float:
        if not self.requests:
            return 0.0
        return sum(r.context_len for r in self.requests) / len(self.requests)

    @property
    def max_context(self) -> int:
        return max((r.context_len for r in self.requests), default=0)
