"""Paged KV-cache pool (the serving-side half of Harli's unified allocator).

Layout mirrors the paper's §4.2 two-level organisation on TPU terms:
  * the *pool* is one pre-allocated array of pages:
      kv_pages: (n_layers, 2, num_pages, page_tokens, kv_heads, head_dim)
  * a *page table* per request maps logical token blocks -> physical pages
  * page accounting (which pages are free / owned by KV / lent to the
    finetune window) lives in core/allocator.py — this module is the
    mechanical pool + gather/scatter paths.

The per-slot "dense" cache used by model.decode_step is the degenerate case
page_tokens == S_max with one page per slot; the paged path below is what the
Pallas decode kernel consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class PagePoolSpec:
    n_layers: int
    num_pages: int
    page_tokens: int
    kv_heads: int
    head_dim: int
    dtype: object = jnp.bfloat16

    @property
    def page_bytes(self) -> int:
        itemsize = jnp.dtype(self.dtype).itemsize
        return (self.n_layers * 2 * self.page_tokens * self.kv_heads
                * self.head_dim * itemsize)

    def alloc(self) -> jax.Array:
        return jnp.zeros((self.n_layers, 2, self.num_pages, self.page_tokens,
                          self.kv_heads, self.head_dim), self.dtype)


def spec_for(cfg: ModelConfig, num_pages: int, page_tokens: int = 16
             ) -> PagePoolSpec:
    return PagePoolSpec(
        n_layers=len(cfg.attn_layer_indices()) or 1,
        num_pages=num_pages, page_tokens=page_tokens,
        kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim)


class PageTableManager:
    """Host-side page tables: request -> list of physical pages.

    Allocation order is FIFO over a free list; the unified allocator may
    shrink the usable region (lending pages to the finetune window), which
    is enforced here via ``set_usable``.
    """

    def __init__(self, spec: PagePoolSpec, max_slots: int,
                 max_pages_per_seq: int):
        self.spec = spec
        self.max_slots = max_slots
        self.max_pages_per_seq = max_pages_per_seq
        self.free: List[int] = list(range(spec.num_pages))
        self.usable = spec.num_pages
        self.tables: Dict[int, List[int]] = {}      # slot -> pages
        self.lengths: Dict[int, int] = {}           # slot -> tokens stored

    # -- accounting ------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.spec.num_pages - len(self.free)

    def set_usable(self, usable_pages: int) -> None:
        """Unified-allocator hook: cap how many pages KV may occupy."""
        self.usable = usable_pages

    def can_alloc(self, n_tokens: int) -> bool:
        need = self._pages_needed(n_tokens)
        return (self.pages_in_use + need) <= self.usable and \
            len(self.free) >= need

    def _pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.spec.page_tokens)

    # -- lifecycle ---------------------------------------------------------
    def admit(self, slot: int, prompt_len: int) -> bool:
        need = self._pages_needed(prompt_len)
        if not self.can_alloc(prompt_len) or slot in self.tables:
            return False
        self.tables[slot] = [self.free.pop() for _ in range(need)]
        self.lengths[slot] = prompt_len
        return True

    def extend(self, slot: int, n_tokens: int = 1) -> bool:
        """Grow a sequence; allocates a new page on boundary crossings."""
        cur = self.lengths[slot]
        need = self._pages_needed(cur + n_tokens) - len(self.tables[slot])
        if need > 0:
            if len(self.free) < need or \
                    self.pages_in_use + need > self.usable:
                return False
            self.tables[slot] += [self.free.pop() for _ in range(need)]
        self.lengths[slot] = cur + n_tokens
        return True

    def release(self, slot: int) -> None:
        self.free.extend(self.tables.pop(slot, []))
        self.lengths.pop(slot, None)

    def table_array(self, slots: List[int]) -> np.ndarray:
        """(len(slots), max_pages_per_seq) int32, -1 padded."""
        out = np.full((len(slots), self.max_pages_per_seq), -1, np.int32)
        for i, s in enumerate(slots):
            pages = self.tables.get(s, [])
            out[i, :len(pages)] = pages
        return out


# ------------------------------------------------------- paged gather ops --
def paged_read(pool: jax.Array, page_table: jax.Array, layer: int
               ) -> Tuple[jax.Array, jax.Array]:
    """Gather a layer's K/V for a batch.

    pool: (L, 2, P, pt, KV, hd); page_table: (B, n_pages) int32 (-1 pad).
    Returns k, v: (B, n_pages*pt, KV, hd); padded pages read page 0 but are
    masked by kv_pos logic downstream.
    """
    pt = jnp.maximum(page_table, 0)
    k = pool[layer, 0][pt]                     # (B, n_pages, ptok, KV, hd)
    v = pool[layer, 1][pt]
    B, n_pages, ptok, KV, hd = k.shape
    return (k.reshape(B, n_pages * ptok, KV, hd),
            v.reshape(B, n_pages * ptok, KV, hd))


def paged_write(pool: jax.Array, page_table: jax.Array, layer: int,
                positions: jax.Array, k_new: jax.Array, v_new: jax.Array
                ) -> jax.Array:
    """Scatter one token per request into the pool.

    positions: (B,) absolute token index; k_new/v_new: (B, KV, hd)."""
    ptok = pool.shape[3]
    page_idx = positions // ptok
    slot_in_page = positions % ptok
    B = positions.shape[0]
    phys = jnp.take_along_axis(jnp.maximum(page_table, 0),
                               page_idx[:, None], axis=1)[:, 0]
    pool = pool.at[layer, 0, phys, slot_in_page].set(
        k_new.astype(pool.dtype))
    pool = pool.at[layer, 1, phys, slot_in_page].set(
        v_new.astype(pool.dtype))
    return pool


def kv_positions(page_table: jax.Array, lengths: jax.Array, page_tokens: int
                 ) -> jax.Array:
    """(B, n_pages*pt) absolute positions for gathered caches (-1 invalid)."""
    B, n_pages = page_table.shape
    logical = (jnp.arange(n_pages * page_tokens)[None, :]
               .astype(jnp.int32))                     # position if contiguous
    valid = (logical < lengths[:, None]) & \
        (jnp.repeat(page_table, page_tokens, axis=1) >= 0)
    return jnp.where(valid, logical, -1)
