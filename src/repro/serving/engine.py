"""Continuous-batching decode engine (real-compute path).

This is the decode *instance* of the disaggregated deployment (paper §2.1):
prefill runs out-of-band (a separate instance; here a jitted prefill call),
decode proceeds in rounds over a fixed slot array with continuous batching.
Harli's scheduler hooks the round boundary (``round_hook``) to co-schedule
finetune layer-units; the discrete-event counterpart used for paper-scale
experiments lives in core/simulator.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.serving.kv_cache import PageTableManager, spec_for
from repro.serving.request import Phase, Request


@dataclasses.dataclass
class EngineMetrics:
    decode_rounds: int = 0
    tokens_out: int = 0
    prefills: int = 0
    rejected_admissions: int = 0
    round_batch_sizes: List[int] = dataclasses.field(default_factory=list)


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 s_max: int = 256, enc_len: int = 0, use_kernels: bool = False,
                 page_tokens: int = 16, num_pages: Optional[int] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.s_max = s_max
        self.enc_len = enc_len
        self.rng = np.random.default_rng(seed)
        self.cache = MD.init_cache(cfg, max_slots, s_max, enc_len=enc_len)
        self.metrics = EngineMetrics()
        # page accounting (Harli's allocator plugs in via set_usable)
        npages = num_pages or max_slots * (-(-s_max // page_tokens))
        self.pages = PageTableManager(spec_for(cfg, npages, page_tokens),
                                      max_slots, -(-s_max // page_tokens))
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.last_token = np.zeros((max_slots,), np.int32)

        self._prefill = jax.jit(
            lambda p, b, c: MD.prefill(p, cfg, b, c))
        self._decode = jax.jit(
            lambda p, t, q, c: MD.decode_step(p, cfg, t, q, c,
                                              use_kernels=use_kernels))

    # ------------------------------------------------------------- admit --
    def try_admit(self, req: Request, prompt_tokens: np.ndarray,
                  extras: Optional[Dict] = None) -> bool:
        slot = next((i for i, s in enumerate(self.slots) if s is None), None)
        if slot is None or not self.pages.admit(slot, req.prompt_len):
            self.metrics.rejected_admissions += 1
            return False
        req.slot, req.phase = slot, Phase.PREFILLING
        self.slots[slot] = req
        batch = {"tokens": jnp.asarray(prompt_tokens[None, :])}
        if extras:
            batch.update({k: jnp.asarray(v)[None] for k, v in extras.items()})
        one_cache = MD.init_cache(self.cfg, 1, self.s_max,
                                  enc_len=self.enc_len)
        logits, one_cache = self._prefill(self.params, batch, one_cache)
        self._insert_slot_cache(slot, one_cache)
        tok = int(jnp.argmax(logits[0]))
        self.last_token[slot] = tok
        req.generated = 1
        req.phase = Phase.DECODING
        self.metrics.prefills += 1
        self.metrics.tokens_out += 1
        return True

    def _insert_slot_cache(self, slot: int, one_cache) -> None:
        def put(dst, src):
            return dst.at[slot].set(src[0])
        self.cache = jax.tree.map(put, self.cache, one_cache)

    # ------------------------------------------------------------- rounds --
    def active_requests(self) -> List[Request]:
        return [r for r in self.slots if r is not None and
                r.phase == Phase.DECODING]

    def decode_round(self) -> Dict[int, int]:
        """One decode step over all active slots. Returns {rid: token}."""
        active = [(i, r) for i, r in enumerate(self.slots)
                  if r is not None and r.phase == Phase.DECODING]
        if not active:
            return {}
        tokens = jnp.asarray(self.last_token)
        positions = np.zeros((self.max_slots,), np.int32)
        for i, r in active:
            positions[i] = r.context_len  # index of the token being written
        logits, self.cache = self._decode(self.params, tokens,
                                          jnp.asarray(positions), self.cache)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)

        out: Dict[int, int] = {}
        self.metrics.decode_rounds += 1
        self.metrics.round_batch_sizes.append(len(active))
        for i, r in active:
            if not self.pages.extend(r.slot, 1):
                continue  # memory pressure: request stalls this round
            self.last_token[i] = next_tokens[i]
            r.generated += 1
            self.metrics.tokens_out += 1
            out[r.rid] = int(next_tokens[i])
            if r.generated >= r.max_new_tokens or \
                    r.context_len >= self.s_max - 1:
                r.phase = Phase.DONE
                self.pages.release(r.slot)
                self.slots[i] = None
        return out

    # ---------------------------------------------------------------- run --
    def run_trace(self, reqs: List[Request], vocab: Optional[int] = None,
                  max_rounds: int = 10_000) -> EngineMetrics:
        """Drive the engine to completion in round-order (arrival order)."""
        vocab = vocab or self.cfg.vocab_size
        pending = sorted(reqs, key=lambda r: r.arrival)
        qi = 0
        rounds = 0
        while rounds < max_rounds:
            while qi < len(pending):
                r = pending[qi]
                toks = self.rng.integers(0, vocab, size=r.prompt_len,
                                         dtype=np.int32)
                extras = self._stub_extras(r)
                if self.try_admit(r, toks, extras):
                    qi += 1
                else:
                    break
            if not self.active_requests() and qi >= len(pending):
                break
            self.decode_round()
            rounds += 1
        return self.metrics

    def _stub_extras(self, req: Request) -> Optional[Dict]:
        cfg = self.cfg
        if cfg.frontend == "vision" and cfg.frontend_tokens:
            return {"frontend": self.rng.normal(
                size=(cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}
        if cfg.enc_layers:
            return {"enc_frames": self.rng.normal(
                size=(max(self.enc_len, 1), cfg.d_model)).astype(np.float32)}
        return None
