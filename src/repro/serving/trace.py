"""Synthetic Splitwise-like LLM request trace.

The paper drives evaluation with the Microsoft Azure LLM inference trace
(Patel et al., ISCA'24), which is not available offline. This generator
reproduces its load characteristics qualitatively (DESIGN.md §2): bursty
Gamma inter-arrivals with a slowly-varying rate envelope, lognormal prompt
lengths, and lognormal output lengths — tuned so a single decode instance
sees batch sizes fluctuating roughly 0–60 (paper Fig. 3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import (GROUP_SEG_BASE, SESSION_SEG_BASE,
                                   Request)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    duration_s: float = 3600.0
    mean_rps: float = 5.3            # ~19k requests/hour (paper §8.1)
    burstiness: float = 0.35         # gamma shape (lower = burstier)
    rate_period_s: float = 600.0     # load-envelope oscillation period
    rate_amplitude: float = 0.6      # envelope swing (fraction of mean)
    prompt_median: int = 1024
    prompt_sigma: float = 0.8        # lognormal sigma
    prompt_max: int = 8192
    output_median: int = 128
    output_sigma: float = 0.9
    output_max: int = 1024
    # multiplicative flash-crowd window (spike preset; 1.0 = disabled)
    spike_mult: float = 1.0
    spike_start_frac: float = 0.4    # window position, fraction of duration
    spike_dur_frac: float = 0.15
    # sticky sessions (session_affinity routing): 0 = sessionless trace.
    # Session ids are drawn Zipf-like from a separate RNG stream so enabling
    # them never perturbs the arrival/length draws of an existing seed.
    n_sessions: int = 0
    session_zipf_a: float = 1.2      # few hot sessions, long cold tail
    # multi-tenant adapter traffic (core/adapters.py): per-tenant arrival
    # weights; empty = single-tenant (every request serves the base model,
    # adapter_id -1). Tenant draws use their own RNG stream (like session
    # ids) so enabling tenants never perturbs an existing seed's trace.
    tenant_weights: Tuple[float, ...] = ()
    # cross-session shared prompt prefixes (core/prefix_tree.py): each
    # session belongs to one of ``shared_prefix_groups`` groups whose
    # requests open with the same ``shared_prefix_tokens``-token system
    # prompt, expressed as Request.prefix_segments. 0 groups = disabled.
    # Group assignment uses its own RNG stream (like sessions/tenants) so
    # enabling it never perturbs an existing seed's trace.
    shared_prefix_groups: int = 0
    shared_prefix_tokens: int = 0
    seed: int = 0


def generate(cfg: TraceConfig = TraceConfig()) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    reqs: List[Request] = []
    spike_lo = cfg.spike_start_frac * cfg.duration_s
    spike_hi = spike_lo + cfg.spike_dur_frac * cfg.duration_s
    t, rid = 0.0, 0
    while t < cfg.duration_s:
        envelope = 1.0 + cfg.rate_amplitude * math.sin(
            2 * math.pi * t / cfg.rate_period_s)
        if cfg.spike_mult != 1.0 and spike_lo <= t < spike_hi:
            envelope *= cfg.spike_mult
        rate = max(cfg.mean_rps * envelope, 1e-3)
        # gamma-distributed gap with mean 1/rate, shape = burstiness
        gap = rng.gamma(cfg.burstiness, 1.0 / (rate * cfg.burstiness))
        t += gap
        if t >= cfg.duration_s:
            break
        p = int(min(rng.lognormal(math.log(cfg.prompt_median),
                                  cfg.prompt_sigma), cfg.prompt_max))
        o = int(min(rng.lognormal(math.log(cfg.output_median),
                                  cfg.output_sigma), cfg.output_max))
        reqs.append(Request(rid=rid, arrival=t, prompt_len=max(p, 1),
                            max_new_tokens=max(o, 1)))
        rid += 1
    if cfg.n_sessions > 0:
        srng = np.random.default_rng(cfg.seed + 104729)
        for r in reqs:
            r.session_id = int(srng.zipf(cfg.session_zipf_a)
                               % cfg.n_sessions)
    if cfg.tenant_weights:
        trng = np.random.default_rng(cfg.seed + TENANT_SEED_SALT)
        w = np.asarray(cfg.tenant_weights, dtype=float)
        p = w / w.sum()
        for r in reqs:
            r.adapter_id = int(trng.choice(len(p), p=p))
    if cfg.shared_prefix_groups > 0 and cfg.shared_prefix_tokens > 0 \
            and cfg.n_sessions > 0:
        grng = np.random.default_rng(cfg.seed + SHARED_PREFIX_SEED_SALT)
        group_of = grng.integers(cfg.shared_prefix_groups,
                                 size=cfg.n_sessions)
        for r in reqs:
            # the system prompt covers at most the cacheable prompt (the
            # final token is never cached); too-short prompts stay opaque
            sys_len = min(cfg.shared_prefix_tokens, r.prompt_len - 1)
            rest = r.prompt_len - sys_len
            if sys_len <= 0 or rest <= 0:
                continue
            g = int(group_of[r.session_id])
            r.prefix_segments = (
                (GROUP_SEG_BASE + g, sys_len),
                (SESSION_SEG_BASE + r.session_id, rest),
            )
    return reqs


# ---------------------------------------------------- failure injection
# Own RNG stream salt (like the session stream's 104729): a failure
# schedule for seed s never perturbs the arrival/length draws of seed s.
FAILURE_SEED_SALT = 92821

# Tenant-assignment stream salt (same isolation property as above).
TENANT_SEED_SALT = 74093

# Session->shared-prefix-group stream salt (same isolation property).
SHARED_PREFIX_SEED_SALT = 48611


@dataclasses.dataclass(frozen=True)
class FailureConfig:
    """Failure/preemption injection for the cluster layer (core/cluster.py,
    ``ClusterConfig.failures``). Kills arrive as a Poisson process over the
    whole fleet; each event takes one victim (a live instance, or an active
    pooled prefill worker) chosen uniformly from the eligible candidates.

    ``warning_s > 0`` turns hard kills into spot-style preemptions: the
    victim gets a notice, drains gracefully (no new dispatches, finetune
    commits a final checkpoint and stops) and is hard-killed only if work
    remains at the deadline. ``checkpoint_interval_s`` is the cadence at
    which colocated/dedicated finetune jobs commit progress through the
    fault-tolerance ``CheckpointManager`` — a kill rolls the job back to
    its last commit, and each commit's device->host stream time is charged
    to the finetune quantum budget (``CostModel.checkpoint_time``).
    0 disables checkpointing (a kill loses all finetune progress)."""
    rate_per_min: float = 0.0        # fleet-wide Poisson kill rate; 0 = off
    warning_s: float = 0.0           # preemption notice; 0 = hard kill
    start_s: float = 0.0             # grace period before the first event
    checkpoint_interval_s: float = 20.0
    checkpoint_dir: Optional[str] = None   # None = private temp dir
    seed: int = 0


class FailureSchedule:
    """Seeded Poisson kill times + deterministic victim choice.

    The schedule is fully determined by ``(cfg, duration_s)`` — two runs
    with the same failure config see identical kill times regardless of
    mode or fleet shape, so harli-vs-separate comparisons at one churn
    rate face the same storm (victim draws consume one RNG step per
    event, keeping the choice sequence aligned across runs too)."""

    def __init__(self, cfg: FailureConfig, duration_s: float):
        self.cfg = cfg
        self.events: List[float] = []
        rng = np.random.default_rng(cfg.seed + FAILURE_SEED_SALT)
        if cfg.rate_per_min > 0:
            rate_s = cfg.rate_per_min / 60.0
            t = cfg.start_s
            while True:
                t += float(rng.exponential(1.0 / rate_s))
                if t >= duration_s:
                    break
                self.events.append(t)
        self._victim_rng = np.random.default_rng(
            cfg.seed + FAILURE_SEED_SALT + 1)
        self._cursor = 0

    def pop_due(self, now: float) -> List[float]:
        """Event times that have fired by ``now`` (consumed exactly once)."""
        out = []
        while self._cursor < len(self.events) \
                and self.events[self._cursor] <= now:
            out.append(self.events[self._cursor])
            self._cursor += 1
        return out

    def pick(self, candidates: Sequence[Tuple[str, int]]) -> Tuple[str, int]:
        """Uniform victim among the (kind, id) candidates. One RNG draw per
        call, even for a single candidate, so the draw sequence stays
        aligned across runs with different fleet shapes."""
        assert candidates, "pick() on an empty candidate list"
        ix = int(self._victim_rng.integers(len(candidates)))
        return candidates[ix]


# ------------------------------------------------- multi-tenant scenarios
# Workload-shape presets for the cluster layer (core/cluster.py): same
# generator, different envelope/burstiness/length mixes. Each models a
# tenant class a MaaS fleet must absorb (steady API traffic, a daily cycle,
# a flash crowd, agentic long-tail jobs, chatbot sessions with shared
# prompt prefixes).
SCENARIOS = ("steady", "diurnal", "spike", "heavy_tail", "session_heavy",
             "multi_tenant", "shared_prefix")

# multi_tenant default arrival mix: a few hot tenants, a long-ish tail —
# the regime adapter_placement policies must pack/replicate for.
DEFAULT_TENANT_WEIGHTS = (0.4, 0.3, 0.2, 0.1)


def scenario_config(name: str, duration_s: float = 600.0,
                    mean_rps: float = 5.3, seed: int = 0,
                    n_sessions: int = 0,
                    tenant_weights: Tuple[float, ...] = ()) -> TraceConfig:
    base = dict(duration_s=duration_s, mean_rps=mean_rps, seed=seed,
                n_sessions=n_sessions, tenant_weights=tenant_weights)
    if name == "steady":
        # near-Poisson arrivals, flat envelope: the autoscaler baseline
        return TraceConfig(burstiness=1.0, rate_amplitude=0.05, **base)
    if name == "diurnal":
        # one slow day/night cycle across the trace; moderate bursts
        return TraceConfig(burstiness=0.5, rate_amplitude=0.8,
                           rate_period_s=duration_s, **base)
    if name == "spike":
        # steady background + a 4x flash crowd over 15% of the trace
        return TraceConfig(burstiness=1.0, rate_amplitude=0.05,
                           spike_mult=4.0, **base)
    if name == "heavy_tail":
        # very bursty arrivals, fat prompt/output tails (agentic traffic)
        return TraceConfig(burstiness=0.2, rate_amplitude=0.3,
                           prompt_sigma=1.3, output_sigma=1.4,
                           output_max=2048, **base)
    if name == "session_heavy":
        # chatbot traffic: a small set of hot sessions keeps returning
        # with near-identical long prompts (shared conversation history),
        # the regime sticky routing + prefix caching targets. Low prompt
        # sigma keeps per-session prompts close in length, so a cached
        # prefix covers most of the next turn's prompt.
        base["n_sessions"] = n_sessions if n_sessions > 0 else 12
        return TraceConfig(burstiness=0.8, rate_amplitude=0.1,
                           prompt_sigma=0.35, **base)
    if name == "shared_prefix":
        # session_heavy traffic where sessions additionally share a few
        # long system prompts (per-tenant templates): the regime the
        # cross-session radix tree + gossip routing targets. Many more
        # sessions than session_heavy — single-session stickiness alone
        # cannot keep the fleet warm, shared prefixes can.
        base["n_sessions"] = n_sessions if n_sessions > 0 else 32
        return TraceConfig(burstiness=0.8, rate_amplitude=0.1,
                           prompt_sigma=0.35, shared_prefix_groups=4,
                           shared_prefix_tokens=384, **base)
    if name == "multi_tenant":
        # MaaS adapter tenancy: several tenants' traffic multiplexed over
        # one fleet, skewed toward a few hot adapters; moderate bursts so
        # placement (not raw capacity) dominates the outcome
        if not base["tenant_weights"]:
            base["tenant_weights"] = DEFAULT_TENANT_WEIGHTS
        return TraceConfig(burstiness=0.7, rate_amplitude=0.2, **base)
    raise ValueError(f"unknown scenario {name!r}; choose from {SCENARIOS}")


def generate_scenario(name: str, duration_s: float = 600.0,
                      mean_rps: float = 5.3, seed: int = 0,
                      n_sessions: int = 0,
                      tenant_weights: Tuple[float, ...] = ()
                      ) -> List[Request]:
    return generate(scenario_config(name, duration_s, mean_rps, seed,
                                    n_sessions=n_sessions,
                                    tenant_weights=tenant_weights))


def peak_rps(reqs: List[Request], window_s: float = 10.0) -> float:
    """Max windowed arrival rate — the load-shape metric the scenario
    tests assert on (spike peak >> steady peak at equal mean)."""
    if not reqs:
        return 0.0
    arr = sorted(r.arrival for r in reqs)
    best, lo = 0, 0
    for hi in range(len(arr)):
        while arr[hi] - arr[lo] > window_s:
            lo += 1
        best = max(best, hi - lo + 1)
    return best / window_s


def controlled_load(phases=((8, 60.0), (42, 60.0), (24, 60.0)),
                    prompt_len: int = 512, output_len: int = 400,
                    seed: int = 0) -> List[Request]:
    """The §8.5 controlled trace: light (bs=8) -> heavy (bs=42) -> medium
    (bs=24). Arrival rates chosen so steady-state decode bs ≈ target."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    t, rid = 0.0, 0
    t_phase = 0.0
    for target_bs, dur in phases:
        # Little's law: bs = rate * decode_time_per_request
        # assume ~25ms/token -> request residency ≈ output_len * 0.025
        rate = target_bs / (output_len * 0.025)
        end = t_phase + dur
        while t < end:
            t += rng.exponential(1.0 / rate)
            if t >= end:
                break
            reqs.append(Request(rid=rid, arrival=t, prompt_len=prompt_len,
                                max_new_tokens=output_len))
            rid += 1
        t_phase = end
        t = max(t, t_phase)
    return reqs
