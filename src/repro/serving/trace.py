"""Synthetic Splitwise-like LLM request trace.

The paper drives evaluation with the Microsoft Azure LLM inference trace
(Patel et al., ISCA'24), which is not available offline. This generator
reproduces its load characteristics qualitatively (DESIGN.md §2): bursty
Gamma inter-arrivals with a slowly-varying rate envelope, lognormal prompt
lengths, and lognormal output lengths — tuned so a single decode instance
sees batch sizes fluctuating roughly 0–60 (paper Fig. 3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    duration_s: float = 3600.0
    mean_rps: float = 5.3            # ~19k requests/hour (paper §8.1)
    burstiness: float = 0.35         # gamma shape (lower = burstier)
    rate_period_s: float = 600.0     # load-envelope oscillation period
    rate_amplitude: float = 0.6      # envelope swing (fraction of mean)
    prompt_median: int = 1024
    prompt_sigma: float = 0.8        # lognormal sigma
    prompt_max: int = 8192
    output_median: int = 128
    output_sigma: float = 0.9
    output_max: int = 1024
    seed: int = 0


def generate(cfg: TraceConfig = TraceConfig()) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    reqs: List[Request] = []
    t, rid = 0.0, 0
    while t < cfg.duration_s:
        envelope = 1.0 + cfg.rate_amplitude * math.sin(
            2 * math.pi * t / cfg.rate_period_s)
        rate = max(cfg.mean_rps * envelope, 1e-3)
        # gamma-distributed gap with mean 1/rate, shape = burstiness
        gap = rng.gamma(cfg.burstiness, 1.0 / (rate * cfg.burstiness))
        t += gap
        if t >= cfg.duration_s:
            break
        p = int(min(rng.lognormal(math.log(cfg.prompt_median),
                                  cfg.prompt_sigma), cfg.prompt_max))
        o = int(min(rng.lognormal(math.log(cfg.output_median),
                                  cfg.output_sigma), cfg.output_max))
        reqs.append(Request(rid=rid, arrival=t, prompt_len=max(p, 1),
                            max_new_tokens=max(o, 1)))
        rid += 1
    return reqs


def controlled_load(phases=((8, 60.0), (42, 60.0), (24, 60.0)),
                    prompt_len: int = 512, output_len: int = 400,
                    seed: int = 0) -> List[Request]:
    """The §8.5 controlled trace: light (bs=8) -> heavy (bs=42) -> medium
    (bs=24). Arrival rates chosen so steady-state decode bs ≈ target."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    t, rid = 0.0, 0
    t_phase = 0.0
    for target_bs, dur in phases:
        # Little's law: bs = rate * decode_time_per_request
        # assume ~25ms/token -> request residency ≈ output_len * 0.025
        rate = target_bs / (output_len * 0.025)
        end = t_phase + dur
        while t < end:
            t += rng.exponential(1.0 / rate)
            if t >= end:
                break
            reqs.append(Request(rid=rid, arrival=t, prompt_len=prompt_len,
                                max_new_tokens=output_len))
            rid += 1
        t_phase = end
        t = max(t, t_phase)
    return reqs
