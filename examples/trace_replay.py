"""Paper-scale experiment in one command: replay a Splitwise-like trace
against the TPU v5e cost model under the three deployment modes and print
the Fig. 11 comparison (finetune throughput + decode QoS).

    PYTHONPATH=src python examples/trace_replay.py \
        [--duration 120] [--rps 6] [--inf llama3-8b] [--ft qwen2.5-7b]
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.core.simulator import SimConfig, simulate
from repro.serving.request import Request
from repro.serving.trace import TraceConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--rps", type=float, default=6.0)
    ap.add_argument("--inf", default="llama3-8b")
    ap.add_argument("--ft", default="llama3-8b")
    ap.add_argument("--qos-ms", type=float, default=40.0)
    ap.add_argument("--share-base-weights", action="store_true",
                    help="beyond-paper: share the frozen base between "
                         "serving and finetune (same-model pairs)")
    args = ap.parse_args()

    cfg_i, cfg_f = get_config(args.inf), get_config(args.ft)
    base = generate(TraceConfig(duration_s=args.duration, mean_rps=args.rps,
                                seed=1))
    print(f"{len(base)} requests over {args.duration:.0f}s; "
          f"inference={cfg_i.name} finetune={cfg_f.name} "
          f"QoS={args.qos_ms:.0f}ms TPOT\n")
    out = {}
    for mode in ("separate", "static", "harli"):
        reqs = [Request(rid=r.rid, arrival=r.arrival,
                        prompt_len=r.prompt_len,
                        max_new_tokens=r.max_new_tokens) for r in base]
        res = simulate(cfg_i, cfg_f, reqs, SimConfig(
            mode=mode, qos_s=args.qos_ms / 1e3, seed=2,
            share_base_weights=args.share_base_weights))
        out[mode] = res
        p50 = np.percentile(res.tpot, 50) * 1e3 if res.tpot else 0
        p99 = np.percentile(res.tpot, 99) * 1e3 if res.tpot else 0
        print(f"{mode:9s} ft_throughput={res.ft_throughput:6.2f} "
              f"(iters/s x batch)  TPOT p50={p50:5.1f}ms p99={p99:5.1f}ms "
              f"QoS-violations={res.qos_violation_frac*100:5.2f}%  "
              f"completed={res.completed}")
    h, s, st = out["harli"], out["separate"], out["static"]
    print(f"\nHarli vs SeparateMode: "
          f"{(h.ft_throughput/max(s.ft_throughput,1e-9)-1)*100:+.1f}% "
          f"finetune throughput (paper: +46.2% avg, +92.0% max)")
    print(f"Harli vs StaticMode:   "
          f"{(h.ft_throughput/max(st.ft_throughput,1e-9)-1)*100:+.1f}% "
          f"(static also violates QoS on "
          f"{st.qos_violation_frac*100:.1f}% of tokens)")


if __name__ == "__main__":
    main()
