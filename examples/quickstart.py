"""Quickstart: build a model from the registry, generate a few tokens, and
run one LoRA finetune step — the public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-8b]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import model as MD
from repro.training import peft as P
from repro.training.data import DataConfig, SyntheticCorpus
from repro.training.optimizer import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    args = ap.parse_args()

    # reduced config (same family/features as the full arch, CPU-runnable)
    cfg = smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = MD.init_params(cfg, key)
    print(f"{cfg.name}: {sum(x.size for x in jax.tree.leaves(params)):,} "
          f"params ({cfg.family})")

    # --- generate: prefill a prompt, then decode 8 tokens ----------------
    B, S = 1, 12
    prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    cache = MD.init_cache(cfg, B, S + 16)
    logits, cache = jax.jit(lambda p, b, c: MD.prefill(p, cfg, b, c))(
        params, {"tokens": prompt}, cache)
    decode = jax.jit(lambda p, t, q, c: MD.decode_step(p, cfg, t, q, c))
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(8):
        toks.append(int(tok[0]))
        logits, cache = decode(params, tok,
                               jnp.full((B,), S + i, jnp.int32), cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print("generated token ids:", toks)

    # --- one PEFT (LoRA) step: only adapters train ------------------------
    adapters = MD.init_adapters(cfg, key)
    step = jax.jit(P.make_train_step(cfg, AdamWConfig(lr=1e-3)))
    batch = next(SyntheticCorpus(
        DataConfig(cfg.vocab_size, 16, 2)).batches())
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    adapters, opt, metrics = step(params, adapters, adamw_init(adapters),
                                  batch)
    n_ad = sum(x.size for x in jax.tree.leaves(adapters))
    print(f"LoRA step: loss={float(metrics['loss']):.3f} "
          f"({n_ad:,} trainable adapter params)")


if __name__ == "__main__":
    main()
