"""Harli end-to-end on real compute: a decode instance serving requests
while PEFT layer-units run inside the SAME fused XLA programs, quantum
chosen per round by the QoS scheduler.

    PYTHONPATH=src python examples/colocate_serve.py \
        [--arch llama3-8b] [--ft-arch qwen2.5-7b] [--requests 10]

(Thin wrapper over repro.launch.serve --smoke --colocate.)
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--smoke" not in argv:
        argv.append("--smoke")
    if "--colocate" not in argv:
        argv.append("--colocate")
    sys.argv = [sys.argv[0]] + argv
    main()
