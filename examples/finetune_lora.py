"""LoRA finetuning driver with checkpoint/restart fault tolerance:
trains a (reduced) model for a few hundred steps, checkpointing
asynchronously; re-run with --resume after killing it to continue.

    PYTHONPATH=src python examples/finetune_lora.py \
        [--arch recurrentgemma-2b] [--steps 30] [--layer-units]

(Thin wrapper over repro.launch.train --smoke.)
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--smoke" not in argv:
        argv.append("--smoke")
    if not any(a.startswith("--ckpt-dir") for a in argv):
        argv += ["--ckpt-dir", "/tmp/repro_ckpt"]
    sys.argv = [sys.argv[0]] + argv
    main()
