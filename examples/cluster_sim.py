"""Cluster-scale experiment in one command: route a multi-tenant trace
through the routing plane and compare harli co-location against a
separate-fleet deployment on cluster goodput (DistServe's SLO-attaining
throughput), QoS attainment and finetune throughput.

    PYTHONPATH=src python examples/cluster_sim.py \
        [--scenario spike] [--duration 60] [--rps 10] [--instances 2] \
        [--policy predicted_latency] [--prefill-mode pooled] \
        [--prefill-workers 2] [--chunk-budget 256] [--sessions 32] \
        [--prefix-cache-chunks 16] [--no-autoscale]

Three deployment modes (docs/cluster.md):
  * ``--prefill-mode chained``  — PR 1's per-instance serialized prefill
  * ``--prefill-mode pooled``   — disaggregated prefill pool (default)
  * ``--prefill-mode chunked``  — prefill chunks mixed into decode rounds
    under a QoS-priced per-round token budget (no prefill tier at all)

``--prefill-workers 0`` still selects chained mode for backward
compatibility. With ``--sessions > 0`` every serving instance gets a
session prefix cache, so sticky routing (``--policy session_affinity``)
shortens effective prefill on hits; ``--prefix-cache-chunks 0`` disables
it (the PR 3 cache-less baseline).
"""

import argparse

from repro.configs import get_config
from repro.core.autoscaler import AutoscalerConfig
from repro.core.cluster import ClusterConfig, simulate_cluster
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.prefix_cache import PrefixCacheConfig
from repro.core.router import PREFILL_MODES, POLICIES, RouterConfig
from repro.core.simulator import ChunkedPrefillConfig, SimConfig
from repro.serving.trace import SCENARIOS, generate_scenario, peak_rps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="spike", choices=SCENARIOS)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--rps", type=float, default=10.0)
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--policy", default="least_loaded", choices=POLICIES)
    ap.add_argument("--prefill-mode", default=None, choices=PREFILL_MODES,
                    help="deployment mode; default derives from "
                         "--prefill-workers (0 = chained, else pooled)")
    ap.add_argument("--prefill-workers", type=int, default=2,
                    help="initial prefill-pool size (pooled mode); 0 = "
                         "chained mode")
    ap.add_argument("--prefill-ordering", default="edf",
                    choices=("edf", "fifo"))
    ap.add_argument("--chunk-budget", type=int, default=256,
                    help="initial per-round prefill token budget "
                         "(chunked mode)")
    ap.add_argument("--sessions", type=int, default=0,
                    help="sticky sessions in the trace (session_affinity)")
    ap.add_argument("--prefix-cache-chunks", type=int, default=16,
                    help="per-instance session prefix cache capacity in "
                         "allocator chunks; 0 disables the cache")
    ap.add_argument("--inf", default="llama3-8b")
    ap.add_argument("--ft", default="llama3-8b")
    ap.add_argument("--qos-ms", type=float, default=40.0)
    ap.add_argument("--ttft-slo", type=float, default=4.0)
    ap.add_argument("--no-autoscale", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg_i, cfg_f = get_config(args.inf), get_config(args.ft)
    n_sessions = args.sessions
    if args.policy == "session_affinity" and n_sessions == 0:
        n_sessions = 32          # affinity needs sessions to stick to
    mode = args.prefill_mode
    if mode is None:
        mode = "chained" if args.prefill_workers <= 0 else "pooled"
    elif mode == "pooled" and args.prefill_workers <= 0:
        ap.error("--prefill-mode pooled needs --prefill-workers >= 1 "
                 "(0 selects chained mode)")
    prefill = PrefillPoolConfig(
        n_workers=args.prefill_workers,
        ordering=args.prefill_ordering) if mode == "pooled" else None
    cache = PrefixCacheConfig(chunks=args.prefix_cache_chunks) \
        if n_sessions > 0 and args.prefix_cache_chunks > 0 else None
    tier = {"pooled": f"pool({args.prefill_workers},"
                      f"{args.prefill_ordering})",
            "chained": "per-instance chain",
            "chunked": f"chunked(budget={args.chunk_budget})"}[mode]
    probe = generate_scenario(args.scenario, args.duration, args.rps,
                              seed=args.seed + 1, n_sessions=n_sessions)
    print(f"scenario={args.scenario}: {len(probe)} requests over "
          f"{args.duration:.0f}s (mean {len(probe)/args.duration:.1f} rps, "
          f"peak {peak_rps(probe):.1f} rps)  fleet_0={args.instances}  "
          f"policy={args.policy}  prefill={tier}  "
          f"prefix_cache={'on' if cache else 'off'}  "
          f"autoscale={not args.no_autoscale}")
    print(f"SLOs: TTFT<={args.ttft_slo:.1f}s TPOT<={args.qos_ms:.0f}ms\n")

    out = {}
    for sim_mode in ("separate", "harli"):
        reqs = generate_scenario(args.scenario, args.duration, args.rps,
                                 seed=args.seed + 1, n_sessions=n_sessions)
        res = simulate_cluster(
            cfg_i, cfg_f, reqs,
            SimConfig(mode=sim_mode, qos_s=args.qos_ms / 1e3,
                      seed=args.seed + 2),
            ClusterConfig(
                n_initial=args.instances,
                autoscale=not args.no_autoscale,
                prefill_mode=mode,
                prefill=prefill,
                chunked=ChunkedPrefillConfig(
                    budget_tokens=args.chunk_budget),
                prefix_cache=cache,
                router=RouterConfig(policy=args.policy,
                                    ttft_slo_s=args.ttft_slo,
                                    tpot_slo_s=args.qos_ms / 1e3),
                autoscaler=AutoscalerConfig()))
        out[sim_mode] = res
        s = res.stats
        acts = [d for d in res.decisions if d.action != "none"]
        print(f"{sim_mode:9s} goodput={s.goodput:6.2f} req/s  "
              f"throughput={s.throughput:6.2f} req/s  "
              f"SLO-attain={s.slo_attainment*100:5.1f}%")
        print(f"{'':9s} TTFT-attain={s.ttft_attainment*100:5.1f}% "
              f"TPOT-attain={s.tpot_attainment*100:5.1f}% "
              f"rejected={s.rejected}  "
              f"QoS-violations={res.qos_violation_frac*100:5.2f}%")
        if mode != "chained":
            print(f"{'':9s} TTFT p99={s.ttft_p99:5.2f}s = "
                  f"queue {s.ttft_queue_p99:.2f} + "
                  f"prefill {s.ttft_prefill_p99:.2f} + "
                  f"decode-wait {s.ttft_decode_wait_p99:.2f} (stage p99s)",
                  end="")
            if mode == "pooled":
                print(f"  prefill-pool={res.final_prefill} final / "
                      f"{res.peak_prefill} peak")
            else:
                print(f"  chunk-budget={res.final_chunk_budget} final")
        if cache is not None:
            tot = res.prefix_hits + res.prefix_misses
            print(f"{'':9s} prefix-cache: {res.prefix_hits}/{tot} hits, "
                  f"{res.prefix_hit_tokens} prefill tokens saved")
        print(f"{'':9s} ft_throughput={res.ft_throughput:6.2f} "
              f"(iters/s x batch)  fleet={res.final_fleet} final / "
              f"{res.peak_fleet} peak  scale-actions={len(acts)} "
              f"{[d.action for d in acts]}\n")

    h, s = out["harli"], out["separate"]
    if s.ft_throughput > 0:
        print(f"harli/separate finetune throughput: "
              f"{h.ft_throughput / s.ft_throughput:.2f}x at "
              f"{h.stats.goodput / max(s.stats.goodput, 1e-9):.2f}x goodput")


if __name__ == "__main__":
    main()
