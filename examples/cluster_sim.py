"""Cluster-scale experiment in one command: route a multi-tenant trace
through the routing plane and compare harli co-location against a
separate-fleet deployment on cluster goodput (DistServe's SLO-attaining
throughput), QoS attainment and finetune throughput.

    PYTHONPATH=src python examples/cluster_sim.py \
        [--scenario spike] [--duration 60] [--rps 10] [--instances 2] \
        [--policy predicted_latency] [--prefill-mode pooled] \
        [--prefill-workers 2] [--chunk-budget 256] [--sessions 32] \
        [--prefix-cache-chunks 16] [--gossip-period 2] [--no-autoscale] \
        [--churn-rate 2 --churn-warning 5 --migration-bw 8 --ladder] \
        [--tenants 4 --adapters --adapter-policy affinity_packed]

or rerun a saved experiment exactly:

    PYTHONPATH=src python examples/cluster_sim.py \
        --spec examples/specs/spike_pooled.json

Everything goes through ``ExperimentSpec`` (repro.core.api): the CLI
flags build a spec, ``--spec file.json`` loads one, and either way
``spec.validate()`` rejects contradictory combinations (a chunk budget
in pooled mode, pool workers in chained mode, unknown policy names) with
the fix in the error message instead of silently ignoring the knob.
``--dump-spec out.json`` writes the flags back out as a spec file.

Three deployment modes (docs/cluster.md):
  * ``--prefill-mode chained``  — PR 1's per-instance serialized prefill
  * ``--prefill-mode pooled``   — disaggregated prefill pool (default)
  * ``--prefill-mode chunked``  — prefill chunks mixed into decode rounds
    under a QoS-priced per-round token budget (no prefill tier at all);
    ``--fuse-quantum`` additionally lets chunk-carrying rounds run a
    reduced finetune quantum when the predictor prices both as fitting

``--prefill-workers 0`` still selects chained mode for backward
compatibility. ``--policy`` accepts any registered routing policy —
including plugins like ``cache_aware`` — via the control-plane registry.
With ``--sessions > 0`` every serving instance gets a session prefix
cache, so cache-aware routing (``session_affinity`` / ``cache_aware``)
shortens effective prefill on hits; ``--prefix-cache-chunks 0`` disables
it (the PR 3 cache-less baseline). The cache is a cross-session radix
tree, so requests sharing a system prompt (``--scenario
shared_prefix``) hit each other's entries. ``--gossip-period`` turns on
the asynchronous cache-summary plane (``--gossip-staleness`` /
``--gossip-topk`` tune it) and ``--policy cache_aware_gossip`` routes
from those digests alone — zero synchronous cache peeks at dispatch.

``--tenants N`` splits the trace across N tenants (skewed harmonic
weights) with per-tenant attainment reporting; adding ``--adapters``
closes the finetune->serve loop — each tenant's colocated finetune job
publishes versioned LoRA adapters that decode instances hot-load on
demand (weight bytes charged to the unified allocator). ``--static-
adapters`` freezes publication at v1 (the static-deployment baseline).
"""

import argparse
import dataclasses

from repro.core.adapters import AdapterServingConfig, TenantConfig
from repro.core.api import (ExperimentSpec, SpecError, available_policies,
                            resolve_policy)
from repro.core.autoscaler import AutoscalerConfig
from repro.core.cluster import (ClusterConfig, DegradationConfig,
                                KVMigrationConfig)
from repro.core.gossip import GossipConfig
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.prefix_cache import PrefixCacheConfig
from repro.core.router import RouterConfig
from repro.core.simulator import ChunkedPrefillConfig, SimConfig
from repro.serving.trace import FailureConfig, SCENARIOS, peak_rps


def build_spec(args, ap) -> ExperimentSpec:
    """Translate CLI flags into an ExperimentSpec, erroring loudly on
    contradictory combinations. Mode-gated flags default to None so an
    *explicit* flag is detectable even when its value equals the config
    default (--prefill-workers 2 with chained mode must error, not
    silently match PrefillPoolConfig()); ExperimentSpec.validate() stays
    the deeper net for spec files."""
    sessions_explicit = args.sessions is not None
    for name, default in CLI_DEFAULTS.items():
        if getattr(args, name) is None:
            setattr(args, name, default)
    n_sessions = args.sessions
    policy_cls = resolve_policy("routing", args.policy)
    if n_sessions == 0 and not sessions_explicit \
            and getattr(policy_cls, "needs_sessions", False):
        # session-keyed policies (declared via RoutingPolicy.
        # needs_sessions, plugins included) get sessions by default; an
        # explicit --sessions 0 stays 0 — the user asked for the
        # sessionless baseline
        n_sessions = 32
    mode = args.prefill_mode
    workers = args.prefill_workers
    if mode is None:
        mode = "chained" if workers is not None and workers <= 0 \
            else "pooled"
    if mode == "pooled":
        if workers is not None and workers <= 0:
            ap.error("--prefill-mode pooled needs --prefill-workers >= 1 "
                     "(0 selects chained mode)")
        prefill = PrefillPoolConfig(
            n_workers=workers if workers is not None else 2,
            ordering=args.prefill_ordering or "edf")
    else:
        if workers is not None and workers > 0:
            ap.error(f"--prefill-workers only applies to --prefill-mode "
                     f"pooled (mode is {mode!r}; 0 selects chained)")
        if args.prefill_ordering is not None:
            ap.error(f"--prefill-ordering only applies to --prefill-mode "
                     f"pooled (mode is {mode!r})")
        prefill = None
    if mode != "chunked":
        if args.chunk_budget is not None:
            ap.error(f"--chunk-budget only applies to --prefill-mode "
                     f"chunked (mode is {mode!r})")
        if args.fuse_quantum:
            ap.error(f"--fuse-quantum only applies to --prefill-mode "
                     f"chunked (mode is {mode!r})")
    chunked = ChunkedPrefillConfig(
        budget_tokens=args.chunk_budget if args.chunk_budget is not None
        else 256,
        fuse_quantum=args.fuse_quantum)
    cache = PrefixCacheConfig(chunks=args.prefix_cache_chunks) \
        if n_sessions > 0 and args.prefix_cache_chunks > 0 else None
    if args.gossip_period is None:
        for flag, val in (("--gossip-staleness", args.gossip_staleness),
                          ("--gossip-topk", args.gossip_topk)):
            if val is not None:
                ap.error(f"{flag} only applies with --gossip-period "
                         "(the gossip plane is off without a publish "
                         "cadence)")
        # cache_aware_gossip cannot route without digests, so the policy
        # alone turns the plane on at its defaults
        gossip = GossipConfig() \
            if args.policy == "cache_aware_gossip" else None
    else:
        if cache is None:
            ap.error("--gossip-period needs a per-instance prefix cache "
                     "(--prefix-cache-chunks >= 1 with sessions); there "
                     "is nothing to gossip without one")
        base = GossipConfig()
        gossip = GossipConfig(
            period_s=args.gossip_period,
            staleness_bound_s=args.gossip_staleness
            if args.gossip_staleness is not None
            else 5.0 * args.gossip_period,
            top_k=args.gossip_topk
            if args.gossip_topk is not None else base.top_k)
    if args.churn_rate is None or args.churn_rate <= 0:
        for flag, val in (("--churn-warning", args.churn_warning),
                          ("--churn-checkpoint-interval",
                           args.churn_checkpoint_interval)):
            if val is not None:
                ap.error(f"{flag} only applies with --churn-rate > 0 "
                         "(the fleet is stable without it)")
        failures = None
    else:
        failures = FailureConfig(
            rate_per_min=args.churn_rate,
            warning_s=args.churn_warning
            if args.churn_warning is not None else 0.0,
            checkpoint_interval_s=args.churn_checkpoint_interval
            if args.churn_checkpoint_interval is not None else 20.0,
            seed=args.seed)
    if args.migration_bw is None:
        if args.migration_policy is not None:
            ap.error("--migration-policy only applies with --migration-bw "
                     "(live KV migration is off without a link)")
        migration = None
    else:
        if failures is None or failures.warning_s <= 0:
            ap.error("--migration-bw requires --churn-rate > 0 and "
                     "--churn-warning > 0 (migration only fires on "
                     "preemption warnings)")
        migration = KVMigrationConfig(
            bw_gbps=args.migration_bw,
            policy=args.migration_policy or "kv_headroom")
    if not args.ladder:
        for flag, val in (("--shed-viol-frac", args.shed_viol_frac),
                          ("--shed-backoff-base", args.shed_backoff_base),
                          ("--shed-max-retries", args.shed_max_retries)):
            if val is not None:
                ap.error(f"{flag} only applies with --ladder "
                         "(the degradation ladder is off without it)")
        degradation = None
    else:
        base = DegradationConfig()
        degradation = DegradationConfig(
            shed_viol_frac=args.shed_viol_frac
            if args.shed_viol_frac is not None else base.shed_viol_frac,
            backoff_base_s=args.shed_backoff_base
            if args.shed_backoff_base is not None else base.backoff_base_s,
            max_retries=args.shed_max_retries
            if args.shed_max_retries is not None else base.max_retries)
    if args.tenants is None or args.tenants <= 0:
        for flag, val in (("--adapter-rank", args.adapter_rank),
                          ("--adapter-publish-iters",
                           args.adapter_publish_iters),
                          ("--adapter-policy", args.adapter_policy)):
            if val is not None:
                ap.error(f"{flag} only applies with --tenants >= 1 and "
                         "--adapters")
        if args.adapters or args.static_adapters:
            ap.error("--adapters/--static-adapters require --tenants >= 1 "
                     "(adapters serve tenant traffic)")
        tenants = ()
        adapters = None
    else:
        # skewed harmonic mix: tenant i gets weight 1/(i+1), normalized
        w = [1.0 / (i + 1) for i in range(args.tenants)]
        tot = sum(w)
        tenants = tuple(TenantConfig(name=f"tenant{i}", weight=wi / tot)
                        for i, wi in enumerate(w))
        if not args.adapters and not args.static_adapters:
            for flag, val in (("--adapter-rank", args.adapter_rank),
                              ("--adapter-publish-iters",
                               args.adapter_publish_iters),
                              ("--adapter-policy", args.adapter_policy)):
                if val is not None:
                    ap.error(f"{flag} requires --adapters (tenants "
                             "without adapters serve the base model)")
            adapters = None
        else:
            adapters = AdapterServingConfig(
                rank=args.adapter_rank
                if args.adapter_rank is not None else 16,
                publish_every_iters=args.adapter_publish_iters
                if args.adapter_publish_iters is not None else 1.0,
                continuous=not args.static_adapters,
                policy=args.adapter_policy or "affinity_packed")
    return ExperimentSpec(
        name=f"{args.scenario}_{mode}_{args.policy}",
        inf_model=args.inf, ft_model=args.ft,
        scenario=args.scenario, duration_s=args.duration,
        mean_rps=args.rps, n_sessions=n_sessions, seed=args.seed,
        tenants=tenants,
        sim=SimConfig(mode="harli", qos_s=args.qos_ms / 1e3,
                      seed=args.seed + 2),
        cluster=ClusterConfig(
            n_initial=args.instances,
            autoscale=not args.no_autoscale,
            prefill_mode=mode,
            prefill=prefill,
            chunked=chunked,
            prefix_cache=cache,
            gossip=gossip,
            failures=failures,
            migration=migration,
            degradation=degradation,
            adapters=adapters,
            router=RouterConfig(policy=args.policy,
                                ttft_slo_s=args.ttft_slo,
                                tpot_slo_s=args.qos_ms / 1e3),
            autoscaler=AutoscalerConfig()))


def describe(spec: ExperimentSpec) -> str:
    cl = spec.cluster
    mode = cl.resolved_mode()
    if mode == "pooled":
        p = cl.prefill or PrefillPoolConfig()
        return f"pool({p.n_workers},{p.ordering})"
    if mode == "chunked":
        fused = "+fused-quantum" if cl.chunked.fuse_quantum else ""
        return f"chunked(budget={cl.chunked.budget_tokens}{fused})"
    return "per-instance chain"


CLI_DEFAULTS = dict(scenario="spike", duration=60.0, rps=10.0,
                    instances=2, policy="least_loaded", sessions=0,
                    prefix_cache_chunks=16, inf="llama3-8b",
                    ft="llama3-8b", qos_ms=40.0, ttft_slo=4.0, seed=0)


def main():
    # experiment-shaping flags default to None (filled from CLI_DEFAULTS
    # in build_spec) so --spec can reject any explicit one: a spec file
    # runs as-is, and silently dropping a flag next to it would be the
    # ignored-knob bug class this PR removes
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="run a saved ExperimentSpec JSON as-is (combine "
                         "only with --dump-spec; other flags error)")
    ap.add_argument("--dump-spec", default=None, metavar="FILE",
                    help="write the flag-built spec to FILE and exit")
    ap.add_argument("--scenario", default=None, choices=SCENARIOS)
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--rps", type=float, default=None)
    ap.add_argument("--instances", type=int, default=None)
    ap.add_argument("--policy", default=None,
                    choices=available_policies("routing"))
    ap.add_argument("--prefill-mode", default=None,
                    choices=available_policies("prefill"),
                    help="deployment mode; default derives from "
                         "--prefill-workers (0 = chained, else pooled)")
    ap.add_argument("--prefill-workers", type=int, default=None,
                    help="initial prefill-pool size (pooled mode, default "
                         "2); 0 = chained mode")
    ap.add_argument("--prefill-ordering", default=None,
                    choices=("edf", "fifo"))
    ap.add_argument("--chunk-budget", type=int, default=None,
                    help="initial per-round prefill token budget "
                         "(chunked mode)")
    ap.add_argument("--fuse-quantum", action="store_true",
                    help="chunked mode: fuse a reduced finetune quantum "
                         "into chunk-carrying rounds when the predictor "
                         "prices both as fitting the round budget")
    ap.add_argument("--sessions", type=int, default=None,
                    help="sticky sessions in the trace "
                         "(session_affinity / cache_aware)")
    ap.add_argument("--prefix-cache-chunks", type=int, default=None,
                    help="per-instance session prefix cache capacity in "
                         "allocator chunks; 0 disables the cache")
    ap.add_argument("--gossip-period", type=float, default=None,
                    help="cache-digest publish cadence in seconds; turns "
                         "on the gossip plane (cache_aware_gossip turns "
                         "it on by itself at the defaults)")
    ap.add_argument("--gossip-staleness", type=float, default=None,
                    help="digest staleness bound in seconds (default "
                         "5x the period; requires --gossip-period)")
    ap.add_argument("--gossip-topk", type=int, default=None,
                    help="prefix fingerprints per digest (default 8, "
                         "clamped by the digest byte budget; requires "
                         "--gossip-period)")
    ap.add_argument("--inf", default=None)
    ap.add_argument("--ft", default=None)
    ap.add_argument("--qos-ms", type=float, default=None)
    ap.add_argument("--ttft-slo", type=float, default=None)
    ap.add_argument("--churn-rate", type=float, default=None,
                    help="instance failures per minute (Poisson, seeded); "
                         "0 or unset = stable fleet")
    ap.add_argument("--churn-warning", type=float, default=None,
                    help="spot-style preemption warning in seconds; 0 = "
                         "hard kills (requires --churn-rate)")
    ap.add_argument("--churn-checkpoint-interval", type=float,
                    default=None,
                    help="finetune checkpoint cadence in seconds on "
                         "colocated instances (default 20; requires "
                         "--churn-rate)")
    ap.add_argument("--migration-bw", type=float, default=None,
                    help="live KV migration link bandwidth in GB/s "
                         "(requires --churn-rate > 0 and --churn-warning "
                         "> 0); unset = warned instances drain in place")
    ap.add_argument("--migration-policy", default=None,
                    choices=available_policies("migration"),
                    help="migration destination policy (default "
                         "kv_headroom; requires --migration-bw)")
    ap.add_argument("--ladder", action="store_true",
                    help="enable the overload degradation ladder "
                         "(finetune breaker -> load shedding -> "
                         "hard rejection)")
    ap.add_argument("--shed-viol-frac", type=float, default=None,
                    help="SLO-violation fraction that escalates the "
                         "ladder to load shedding (requires --ladder)")
    ap.add_argument("--shed-backoff-base", type=float, default=None,
                    help="first shed-retry backoff in seconds "
                         "(requires --ladder)")
    ap.add_argument("--shed-max-retries", type=int, default=None,
                    help="shed retries before hard rejection "
                         "(requires --ladder)")
    ap.add_argument("--tenants", type=int, default=None,
                    help="split the trace across N tenants (skewed "
                         "harmonic weights) with per-tenant attainment "
                         "reporting; 0 or unset = single-tenant")
    ap.add_argument("--adapters", action="store_true",
                    help="serve a per-tenant LoRA adapter, continuously "
                         "republished from the colocated finetune jobs "
                         "(requires --tenants >= 1)")
    ap.add_argument("--static-adapters", action="store_true",
                    help="adapter serving with publication frozen at v1 "
                         "(the static-deployment baseline; implies "
                         "--adapters)")
    ap.add_argument("--adapter-rank", type=int, default=None,
                    help="LoRA rank of published adapters (default 16; "
                         "requires --adapters)")
    ap.add_argument("--adapter-publish-iters", type=float, default=None,
                    help="finetune iterations per adapter version "
                         "(default 1; requires --adapters)")
    ap.add_argument("--adapter-policy", default=None,
                    choices=available_policies("adapter_placement"),
                    help="adapter placement policy (default "
                         "affinity_packed; requires --adapters)")
    ap.add_argument("--no-autoscale", action="store_true")
    ap.add_argument("--seed", type=int, default=None)
    args = ap.parse_args()

    if args.spec is not None:
        explicit = [f"--{n.replace('_', '-')}" for n in
                    list(CLI_DEFAULTS) + ["prefill_mode",
                                          "prefill_workers",
                                          "prefill_ordering",
                                          "chunk_budget",
                                          "gossip_period",
                                          "gossip_staleness",
                                          "gossip_topk",
                                          "churn_rate",
                                          "churn_warning",
                                          "churn_checkpoint_interval",
                                          "migration_bw",
                                          "migration_policy",
                                          "shed_viol_frac",
                                          "shed_backoff_base",
                                          "shed_max_retries",
                                          "tenants",
                                          "adapter_rank",
                                          "adapter_publish_iters",
                                          "adapter_policy"]
                    if getattr(args, n) is not None]
        explicit += [f"--{n.replace('_', '-')}" for n in
                     ("fuse_quantum", "no_autoscale", "ladder",
                      "adapters", "static_adapters")
                     if getattr(args, n)]
        if explicit:
            ap.error(f"--spec runs the file as-is; drop "
                     f"{', '.join(explicit)} (edit the spec instead, or "
                     "build one from flags with --dump-spec)")
        try:
            spec = ExperimentSpec.load(args.spec)
            spec.validate()
        except (OSError, SpecError) as e:
            ap.error(str(e))
    else:
        spec = build_spec(args, ap)
        try:
            spec.validate()
        except SpecError as e:
            ap.error(str(e))
    if args.dump_spec is not None:
        spec.save(args.dump_spec)
        print(f"spec written to {args.dump_spec}")
        return

    cl = spec.cluster
    cache = cl.prefix_cache
    churn = ""
    if cl.gossip is not None:
        churn += f"  gossip={cl.gossip.period_s:g}s/" \
                 f"{cl.gossip.staleness_bound_s:g}s" \
                 f"(k={cl.gossip.effective_top_k()})"
    if cl.failures is not None:
        churn = f"  churn={cl.failures.rate_per_min:g}/min"
        if cl.failures.warning_s > 0:
            churn += f" (warn {cl.failures.warning_s:g}s)"
    if cl.migration is not None:
        churn += f"  migration={cl.migration.bw_gbps:g}GB/s" \
                 f"({cl.migration.policy})"
    if cl.degradation is not None:
        churn += "  ladder=on"
    if spec.tenants:
        churn += f"  tenants={len(spec.tenants)}"
        if cl.adapters is not None:
            mode_s = "continuous" if cl.adapters.continuous else "static"
            churn += f"  adapters={mode_s}({cl.adapters.policy})"
    probe = spec.requests()
    print(f"spec={spec.name}  scenario={spec.scenario}: {len(probe)} "
          f"requests over {spec.duration_s:.0f}s "
          f"(mean {len(probe)/spec.duration_s:.1f} rps, "
          f"peak {peak_rps(probe):.1f} rps)  fleet_0={cl.n_initial}  "
          f"policy={cl.router.policy}  prefill={describe(spec)}  "
          f"prefix_cache={'on' if cache else 'off'}  "
          f"autoscale={cl.autoscale}{churn}")
    print(f"SLOs: TTFT<={cl.router.ttft_slo_s:.1f}s "
          f"TPOT<={cl.router.tpot_slo_s*1e3:.0f}ms\n")

    mode = cl.resolved_mode()
    out = {}
    for sim_mode in ("separate", "harli"):
        res = spec.with_mode(sim_mode).run()
        out[sim_mode] = res
        s = res.stats
        acts = [d for d in res.decisions if d.action != "none"]
        print(f"{sim_mode:9s} goodput={s.goodput:6.2f} req/s  "
              f"throughput={s.throughput:6.2f} req/s  "
              f"SLO-attain={s.slo_attainment*100:5.1f}%")
        print(f"{'':9s} TTFT-attain={s.ttft_attainment*100:5.1f}% "
              f"TPOT-attain={s.tpot_attainment*100:5.1f}% "
              f"rejected={s.rejected}  "
              f"QoS-violations={res.qos_violation_frac*100:5.2f}%")
        if cl.failures is not None:
            print(f"{'':9s} churn: {res.failures} kills "
                  f"({res.preemptions} warned), {res.requeued_requests} "
                  f"requeued ({res.requeue_rejected} rejected), "
                  f"ft-iters lost {res.ft_lost_iterations:.1f}, "
                  f"ckpt-commits {res.checkpoint_commits}")
        if cl.migration is not None:
            print(f"{'':9s} migration: {res.migrated_requests} live-"
                  f"migrated ({res.migrated_kv_tokens} KV tokens "
                  f"shipped), {res.migration_reprefills} re-prefilled "
                  f"after losing the race")
        if cl.degradation is not None:
            print(f"{'':9s} ladder: peak level {res.ladder_peak}, "
                  f"{res.breaker_epochs} breaker epochs / "
                  f"{res.shed_epochs} shed epochs, {res.shed_requests} "
                  f"shed ({res.shed_rejected} hard-rejected)")
        if mode != "chained":
            print(f"{'':9s} TTFT p99={s.ttft_p99:5.2f}s = "
                  f"queue {s.ttft_queue_p99:.2f} + "
                  f"prefill {s.ttft_prefill_p99:.2f} + "
                  f"decode-wait {s.ttft_decode_wait_p99:.2f} (stage p99s)",
                  end="")
            if mode == "pooled":
                print(f"  prefill-pool={res.final_prefill} final / "
                      f"{res.peak_prefill} peak")
            else:
                print(f"  chunk-budget={res.final_chunk_budget} final")
        if cache is not None:
            tot = res.prefix_hits + res.prefix_misses
            print(f"{'':9s} prefix-cache: {res.prefix_hits}/{tot} hits, "
                  f"{res.prefix_hit_tokens} prefill tokens saved "
                  f"({res.prefix_shared_hit_tokens} cross-session)")
        if cl.gossip is not None:
            print(f"{'':9s} gossip: {res.gossip_published} digests "
                  f"({res.gossip_bytes}B) published, "
                  f"{res.dispatch_peeks} sync peeks at dispatch, "
                  f"{res.gossip_stale_discards} stale discards, "
                  f"max used age {res.gossip_max_used_age:.1f}s")
        if cl.adapters is not None:
            print(f"{'':9s} adapters: {res.adapter_loads} hot-loads "
                  f"({res.adapter_evictions} evicted, "
                  f"{res.adapter_load_failures} fell back to base), "
                  f"{res.adapter_load_time_s:.2f}s total swap time, "
                  f"versions {res.adapter_versions_published} published "
                  f"/ {res.adapter_versions_served} served")
        if spec.tenants and s.tenants:
            for tid in sorted(s.tenants):
                tn = s.tenants[tid]
                name = spec.tenants[tid].name \
                    if tid < len(spec.tenants) else f"tenant{tid}"
                print(f"{'':9s} [{name:>8s}] offered={tn.offered:4d} "
                      f"attained={tn.attained:4d} "
                      f"TTFT-att={tn.ttft_attainment*100:5.1f}% "
                      f"TPOT-att={tn.tpot_attainment*100:5.1f}% "
                      f"TTFT-p99={tn.ttft_p99:5.2f}s "
                      f"versions={tn.versions_served}")
        print(f"{'':9s} ft_throughput={res.ft_throughput:6.2f} "
              f"(iters/s x batch)  fleet={res.final_fleet} final / "
              f"{res.peak_fleet} peak  scale-actions={len(acts)} "
              f"{[d.action for d in acts]}\n")

    h, s = out["harli"], out["separate"]
    if s.ft_throughput > 0:
        print(f"harli/separate finetune throughput: "
              f"{h.ft_throughput / s.ft_throughput:.2f}x at "
              f"{h.stats.goodput / max(s.stats.goodput, 1e-9):.2f}x goodput")


if __name__ == "__main__":
    main()
