"""Checkpoint/restart, elastic resharding, straggler mitigation."""

import json
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.distributed.fault_tolerance import (CheckpointManager,
                                               StragglerConfig,
                                               StragglerMitigator)
from repro.models import model as MD


def _tree(key):
    cfg = smoke_config("qwen3-8b")
    return MD.init_adapters(cfg, key)


def test_checkpoint_roundtrip(tmp_path, key):
    tree = _tree(key)
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(1, tree)
    out = mgr.restore(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path, key):
    tree = _tree(key)
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree),
                 blocking=False)
        mgr.wait()
    assert mgr.steps() == [3, 4]          # keep=2 garbage collection
    out = mgr.restore(tree, step=4)
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(out)[0]),
        np.asarray(jax.tree.leaves(tree)[0]) + 4)


def test_checkpoint_atomicity(tmp_path, key):
    """A torn write (missing manifest) must be invisible to restore."""
    tree = _tree(key)
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, tree)
    torn = tmp_path / "step_2"
    torn.mkdir()
    (torn / "leaf_00000.npy").write_bytes(b"garbage")   # no manifest
    assert mgr.latest_step() == 1
    mgr.restore(tree)                                    # must not raise


def test_checkpoint_restore_missing(tmp_path, key):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree(key))


def test_checkpoint_gc_keep_zero(tmp_path):
    """keep=0 means retain nothing: every completed save is collected.
    Regression: ``steps[:-0]`` is the empty slice, so keep=0 used to
    silently keep *everything* instead."""
    tree = {"x": np.arange(4)}
    mgr = CheckpointManager(tmp_path, keep=0)
    for step in (1, 2):
        mgr.save(step, tree)
    assert mgr.steps() == []
    assert mgr.latest_step() is None


def test_checkpoint_negative_keep_rejected(tmp_path):
    with pytest.raises(ValueError, match="keep must be >= 0"):
        CheckpointManager(tmp_path, keep=-1)


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.configs import smoke_config
from repro.distributed.fault_tolerance import CheckpointManager, reshard
from repro.distributed import partitioning as PT
from repro.models import model as MD

ckpt_dir = sys.argv[1]
cfg = smoke_config("qwen3-8b")
params = MD.init_params(cfg, jax.random.PRNGKey(0))
mgr = CheckpointManager(ckpt_dir)
mgr.save(1, params)

# restore onto a 2x4 mesh, then elastically onto 1x4 (simulated pod loss)
for shape in ((2, 4), (1, 4)):
    mesh = Mesh(np.asarray(jax.devices()[:shape[0]*shape[1]]).reshape(shape),
                ("data", "model"))
    specs = PT.param_specs(cfg, params, mesh)
    restored = mgr.restore(params, mesh=mesh, specs=specs)
    x = jax.tree.leaves(restored)[0]
    assert len(x.sharding.device_set) >= 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
"""


def test_elastic_restore_subprocess(tmp_path):
    """Restore the same checkpoint onto two different mesh shapes (elastic
    scaling after a pod loss) — runs in a subprocess so the 8-device flag
    never leaks into this test session."""
    script = tmp_path / "elastic.py"
    script.write_text(ELASTIC_SCRIPT)
    r = subprocess.run(
        [sys.executable, str(script), str(tmp_path / "ckpt")],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ,
             "PYTHONPATH": str(Path(__file__).parents[1] / "src")})
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


def test_straggler_mitigator():
    m = StragglerMitigator(StragglerConfig(window=16, deadline_factor=2.0,
                                           cooloff_rounds=4))
    for _ in range(20):
        assert not m.observe(0.010)
    assert m.observe(0.050)               # 5x median -> overrun
    assert m.suppress_quantum
    for _ in range(4):
        m.observe(0.010)
    assert not m.suppress_quantum         # cooloff expired
    assert m.overruns == 1


def test_straggler_deadline_robust_to_noise():
    m = StragglerMitigator(StragglerConfig(window=32, deadline_factor=2.5))
    rng = np.random.default_rng(0)
    overruns = sum(m.observe(float(t))
                   for t in rng.normal(0.02, 0.002, size=200))
    assert overruns == 0                  # 10% noise never trips a 2.5x gate
