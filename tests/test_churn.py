"""Failure/preemption injection: seeded FailureSchedule determinism,
request conservation under churn in every prefill mode, checkpoint-bounded
finetune loss, prefix-cache invalidation on kill, and the zero-churn
bit-identity guarantee (an inert failure layer must not perturb the
stable-fleet path)."""

import dataclasses

import pytest

from repro.configs import get_config
from repro.core.cluster import ClusterConfig, simulate_cluster
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.prefix_cache import PrefixCacheConfig
from repro.core.router import RouterConfig
from repro.core.simulator import (ChunkedPrefillConfig, DecodeInstanceSim,
                                  FinetuneCheckpointer, SimConfig)
from repro.serving.trace import (FAILURE_SEED_SALT, FailureConfig,
                                 FailureSchedule, generate_scenario)

LLAMA = get_config("llama3-8b")


def _run(mode="harli", duration=40.0, rps=8.0, n=3, seed=2,
         failures=None, **cluster_kw):
    reqs = generate_scenario("steady", duration, rps, seed=seed - 1)
    return simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode=mode, seed=seed),
        ClusterConfig(n_initial=n, router=RouterConfig(),
                      failures=failures, **cluster_kw))


# ---------------------------------------------------- FailureSchedule ----
def test_schedule_deterministic_per_seed():
    cfg = FailureConfig(rate_per_min=4.0, seed=11)
    a = FailureSchedule(cfg, 300.0)
    b = FailureSchedule(cfg, 300.0)
    assert a.events and a.events == b.events
    c = FailureSchedule(dataclasses.replace(cfg, seed=12), 300.0)
    assert a.events != c.events


def test_schedule_rate_zero_empty():
    assert FailureSchedule(FailureConfig(rate_per_min=0.0), 300.0) \
        .events == []


def test_schedule_events_in_window():
    cfg = FailureConfig(rate_per_min=10.0, start_s=30.0, seed=5)
    sched = FailureSchedule(cfg, 120.0)
    assert all(30.0 <= t <= 120.0 for t in sched.events)
    assert sched.events == sorted(sched.events)


def test_schedule_pop_due_consumes_in_order():
    sched = FailureSchedule(FailureConfig(rate_per_min=20.0, seed=7), 60.0)
    popped = []
    for t in range(0, 61, 5):
        popped += sched.pop_due(float(t))
    assert popped == sched.events
    assert sched.pop_due(1e9) == []


def test_schedule_not_mixed_with_victim_rng():
    """The kill-time schedule is a function of FailureConfig.seed alone —
    harli and separate fleets face the same storm; only victim picks
    consume the second stream."""
    cfg = FailureConfig(rate_per_min=4.0, seed=11)
    a = FailureSchedule(cfg, 300.0)
    b = FailureSchedule(cfg, 300.0)
    b.pick([("inst", 0), ("inst", 1)])   # victim draw must not shift kills
    assert a.events == b.events
    assert FAILURE_SEED_SALT != 0        # schedule stream != sim stream


# ----------------------------------------------- conservation + counters --
CHURN = FailureConfig(rate_per_min=6.0, checkpoint_interval_s=10.0, seed=4)
MODE_KW = {
    "chained": dict(prefill_mode="chained", prefill=None),
    "pooled": dict(prefill_mode="pooled", prefill=PrefillPoolConfig()),
    "chunked": dict(prefill_mode="chunked", prefill=None,
                    chunked=ChunkedPrefillConfig()),
}


@pytest.mark.parametrize("prefill_mode", list(MODE_KW))
def test_conservation_under_churn(prefill_mode):
    """Kills mid-epoch must not lose or double-count requests in any
    prefill mode — the run's own router/pool audits plus external
    accounting. The failure rate is high enough that the run *must*
    actually kill something for the test to mean anything."""
    res = _run(failures=CHURN, **MODE_KW[prefill_mode])
    assert res.failures > 0, "churn scenario killed nothing"
    s = res.stats
    assert s.routed + s.rejected == s.offered
    assert res.requeued_requests + res.requeue_rejected > 0 \
        or prefill_mode == "chunked"     # chunked may lose only idle insts
    assert res.checkpoint_commits > 0
    assert s.goodput > 0


def test_churn_deterministic_rerun():
    a = _run(failures=CHURN, **MODE_KW["pooled"])
    b = _run(failures=CHURN, **MODE_KW["pooled"])
    assert a.stats == b.stats
    assert (a.failures, a.preemptions, a.requeued_requests,
            a.requeue_rejected, a.ft_lost_iterations,
            a.checkpoint_commits) == \
           (b.failures, b.preemptions, b.requeued_requests,
            b.requeue_rejected, b.ft_lost_iterations,
            b.checkpoint_commits)


def test_zero_churn_bit_identical_to_no_failure_path():
    """An inert failure layer (rate 0, no warning, no checkpointing) must
    reproduce the failures=None run bit-for-bit — the injection hooks are
    pure additions to the epoch loop."""
    base = _run(failures=None)
    inert = _run(failures=FailureConfig(rate_per_min=0.0, warning_s=0.0,
                                        checkpoint_interval_s=0.0))
    assert inert.failures == 0 and inert.checkpoint_commits == 0
    assert base.stats == inert.stats
    assert base.ft_throughput == inert.ft_throughput
    assert [d.action for d in base.decisions] == \
        [d.action for d in inert.decisions]
    assert base.fleet_timeline == inert.fleet_timeline


def test_preemption_warning_drains_gracefully():
    """warning_s > 0 converts hard kills of instances into drain notices:
    preemptions are counted, and because begin_preempt commits a
    checkpoint, warned finetune jobs lose no progress."""
    res = _run(failures=dataclasses.replace(CHURN, warning_s=5.0),
               duration=50.0)
    assert res.preemptions > 0
    assert res.ft_lost_iterations == 0.0
    s = res.stats
    assert s.routed + s.rejected == s.offered


def test_separate_mode_respawns_dedicated_finetune():
    """In separate mode the dedicated finetune host is outside the
    autoscaler's serving loop — the failure layer itself must replace it,
    so finetune throughput survives churn."""
    res = _run(mode="separate", failures=CHURN)
    assert res.failures > 0
    assert res.ft_throughput > 0


# --------------------------------------------------- instance-level kill --
def _inst(tmp_path=None, cfg_ft=LLAMA, **kw):
    sim = SimConfig(mode="harli", seed=0)
    ckpt = None
    if tmp_path is not None:
        ckpt = FinetuneCheckpointer(tmp_path, interval_s=5.0,
                                    commit_time_s=0.01)
    return DecodeInstanceSim(0, LLAMA, cfg_ft, sim, None, 0,
                             ckpt=ckpt, **kw)


def test_kill_rolls_back_to_last_commit(tmp_path):
    """Finetune loss on a kill is bounded by the checkpoint cadence: the
    job resumes at exactly the last committed unit count."""
    inst = _inst(tmp_path)
    inst.ft.units_done = 30
    inst.ckpt.commit(10.0, inst.ft.units_done)
    inst.ft.units_done = 37              # progress after the commit
    lost, ft_lost = inst.kill(20.0)
    assert inst.ft.units_done == 30
    assert inst.ft.cursor == 30 % inst.ft.units_per_iter
    assert ft_lost == pytest.approx(7 / inst.ft.units_per_iter)


def test_kill_without_checkpointer_loses_everything():
    inst = _inst(tmp_path=None)
    inst.ft.units_done = 37
    _, ft_lost = inst.kill(20.0)
    assert inst.ft.units_done == 0
    assert ft_lost == pytest.approx(37 / inst.ft.units_per_iter)


def test_kill_invalidates_prefix_cache():
    """A dead host's KV is gone: every cached session prefix must be
    evicted so post-restart lookups miss instead of claiming dead chunks."""
    inst = _inst(cfg_ft=None, prefix_cache=PrefixCacheConfig(chunks=8))
    inst.prefix_cache.insert(1, 256)
    inst.prefix_cache.insert(2, 128)
    assert len(inst.prefix_cache) == 2
    inst.kill(5.0)
    assert len(inst.prefix_cache) == 0
    assert inst.prefix_cache.used_tokens == 0
