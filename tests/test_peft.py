"""Layer-unit PEFT engine: exact equivalence with the one-shot train step,
gradient accumulation, and loss descent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as MD
from repro.models.config import LoRAConfig, ModelConfig
from repro.training import peft as P
from repro.training.data import DataConfig, Prefetcher, SyntheticCorpus
from repro.training.optimizer import AdamWConfig, adamw_init


def _setup(key, family="dense", **kw):
    base = dict(name="t", family=family, num_layers=3, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=256,
                lora=LoRAConfig(rank=4))
    base.update(kw)
    cfg = ModelConfig(**base)
    params = MD.init_params(cfg, key)
    return cfg, params


def test_unit_engine_equals_train_step(key):
    """One iteration through the lax.switch unit machine must produce
    bit-identical adapters to jax.grad over the whole loss (accum=1)."""
    cfg, params = _setup(key)
    pc = P.PeftConfig(micro_batch=2, seq_len=16, accum=1,
                      opt=AdamWConfig(lr=1e-3, grad_clip=0.0,
                                      warmup_steps=1))
    pf = Prefetcher(SyntheticCorpus(
        DataConfig(cfg.vocab_size, 16, 2, seed=1)).batches(), 2)
    staged = pf.stacked()
    state = P.init_ft_state(cfg, pc, params, key, staged)
    unit = jax.jit(P.make_unit_step(cfg, pc, params))
    for _ in range(P.units_per_iteration(cfg, pc.accum)):
        state = unit(state)

    ts = jax.jit(P.make_train_step(cfg, pc.opt, remat=False))
    ad0 = MD.init_adapters(cfg, key)
    batch = {k: jnp.asarray(v[0]) for k, v in staged.items()}
    ad1, _, metrics = ts(params, ad0, adamw_init(ad0), batch)

    assert float(state["last_loss"]) == pytest.approx(
        float(metrics["loss"]), abs=1e-5)
    for a, b in zip(jax.tree.leaves(state["adapters"]),
                    jax.tree.leaves(ad1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unit_engine_grad_accumulation(key):
    """accum=2 must average gradients over two microbatches."""
    cfg, params = _setup(key)
    pc = P.PeftConfig(micro_batch=2, seq_len=16, accum=2,
                      opt=AdamWConfig(lr=1e-3, grad_clip=0.0,
                                      warmup_steps=1))
    pf = Prefetcher(SyntheticCorpus(
        DataConfig(cfg.vocab_size, 16, 2, seed=2)).batches(), 2)
    staged = pf.stacked()
    state = P.init_ft_state(cfg, pc, params, key, staged)
    unit = jax.jit(P.make_unit_step(cfg, pc, params))
    for _ in range(P.units_per_iteration(cfg, pc.accum)):
        state = unit(state)

    # oracle: grads averaged over both staged microbatches
    ad0 = MD.init_adapters(cfg, key)

    def loss_of(ad):
        total = 0.0
        for i in range(2):
            batch = {k: jnp.asarray(v[i]) for k, v in staged.items()}
            l, _ = MD.loss_fn(params, cfg, batch, adapters=ad, remat=False)
            total = total + l / 2
        return total

    grads = jax.grad(loss_of)(ad0)
    from repro.training.optimizer import adamw_update
    ad1, _ = adamw_update(pc.opt, grads, adamw_init(ad0), ad0)
    for a, b in zip(jax.tree.leaves(state["adapters"]),
                    jax.tree.leaves(ad1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-6, rtol=1e-4)
    assert int(state["consumed"]) == 2


@pytest.mark.parametrize("family,kw", [
    ("dense", {}),
    ("moe", dict(moe=True, num_experts=4, top_k=2, moe_d_ff=48,
                 first_dense_layers=1)),
    ("ssm", dict(d_ff=0, ssm_state=16, ssm_headdim=16, ssm_chunk=4,
                 num_kv_heads=4)),
])
def test_unit_engine_families(family, kw, key):
    """The unit machine must run a full iteration for non-dense families
    (pre-layer units for deepseek-style stacks, SSM mixers)."""
    cfg, params = _setup(key, family=family, **kw)
    pc = P.PeftConfig(micro_batch=2, seq_len=12, accum=1,
                      opt=AdamWConfig(lr=1e-3))
    pf = Prefetcher(SyntheticCorpus(
        DataConfig(cfg.vocab_size, 12, 2, seed=3)).batches(), 2)
    state = P.init_ft_state(cfg, pc, params, key, pf.stacked())
    unit = jax.jit(P.make_unit_step(cfg, pc, params))
    for _ in range(P.units_per_iteration(cfg, pc.accum)):
        state = unit(state)
    assert int(state["iter"]) == 1
    assert np.isfinite(float(state["last_loss"]))


def test_loss_descends(key):
    cfg, params = _setup(key)
    pc = P.PeftConfig(micro_batch=2, seq_len=16, accum=1,
                      opt=AdamWConfig(lr=5e-3, warmup_steps=1))
    data = SyntheticCorpus(DataConfig(cfg.vocab_size, 16, 2, seed=4)).batches()
    batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    step = jax.jit(P.make_train_step(cfg, pc.opt, remat=True))
    ad = MD.init_adapters(cfg, key)
    opt = adamw_init(ad)
    losses = []
    for _ in range(8):
        ad, opt, m = step(params, ad, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses
