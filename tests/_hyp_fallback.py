"""Dependency-free stand-in for the slice of `hypothesis` the suite uses.

The property tests draw random operation sequences; when hypothesis is
installed they get shrinking and example databases for free. When it is
not (the tier-1 container ships without it), this module provides the same
`given/settings/strategies` surface backed by seeded `random.Random`
streams, so every property still runs `max_examples` deterministic cases
per test. No shrinking — a failing example prints its inputs instead.

Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hyp_fallback import given, settings, strategies as st
"""

from __future__ import annotations

import functools
import inspect
import random
from types import SimpleNamespace
from typing import Any, Callable, List

_DEFAULT_EXAMPLES = 20
_SEED = 0x4A71                      # stable across runs and machines


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def _tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def _lists(elements: _Strategy, min_size: int = 0,
           max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


strategies = SimpleNamespace(integers=_integers, floats=_floats,
                             booleans=_booleans, sampled_from=_sampled_from,
                             tuples=_tuples, lists=_lists)


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    """Records max_examples on the (already @given-wrapped) function."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    def deco(fn):
        # hypothesis semantics: positional strategies bind to the RIGHTMOST
        # parameters; anything left over (leading params) is a pytest
        # fixture and stays visible in the wrapper's signature.
        sig = inspect.signature(fn)
        names = [p.name for p in sig.parameters.values()]
        pos_pool = [n for n in names if n not in kw_strategies]
        split = len(pos_pool) - len(arg_strategies)
        assert split >= 0, "more positional strategies than parameters"
        drawn_names = pos_pool[split:]
        fixture_names = [n for n in names
                         if n not in kw_strategies and n not in drawn_names]

        @functools.wraps(fn)
        def wrapper(**fixture_kw):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_EXAMPLES)
            for i in range(n):
                rng = random.Random(_SEED + 7919 * i)
                call = dict(fixture_kw)
                for nm, s in zip(drawn_names, arg_strategies):
                    call[nm] = s.draw(rng)
                for nm, s in kw_strategies.items():
                    call[nm] = s.draw(rng)
                try:
                    fn(**call)
                except BaseException:
                    shown = {nm: call[nm] for nm in call
                             if nm not in fixture_kw}
                    print(f"\n[_hyp_fallback] failing example #{i}: "
                          f"{shown!r}")
                    raise
        wrapper.__signature__ = inspect.Signature(
            [sig.parameters[n] for n in fixture_names])
        return wrapper
    return deco
