"""Multi-LoRA adapter serving: the finetune->serve loop (PR 9).

Layers under test, bottom-up:

  * ``UnifiedAllocator.adapter_reserve/release`` — adapter weight bytes
    as a fourth first-class allocator consumer (conservation, leak
    counter, invariants under churn);
  * ``AdapterRegistry`` / ``AdapterPool`` — monotone versioned publish,
    LRU hot-load/evict with in-use protection;
  * adapters-off bit-identity — ``ClusterConfig.adapters=None`` is
    pinned bit-identical to the PR 7 build in all three prefill modes
    (the determinism contract every PR's default-off feature obeys);
  * the acceptance property — on the multi_tenant scenario, harli
    continuous deployment serves strictly more adapter versions than the
    static deploy-once baseline at per-tenant SLO attainment no worse.
"""

import dataclasses
import json

import pytest

from repro.configs import get_config
from repro.core.adapters import (AdapterPool, AdapterRegistry,
                                 AdapterServingConfig,
                                 InstanceAdapterConfig, TenantConfig,
                                 adapter_bytes)
from repro.core.allocator import AllocatorConfig, UnifiedAllocator
from repro.core.api import ExperimentSpec, SpecError
from repro.core.cluster import ClusterConfig
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.experiment import SCHEMA_VERSION, upgrade_v1
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.simulator import SimConfig

LLAMA = get_config("llama3-8b")


# ------------------------------------------------ allocator adapter pool --
def _alloc():
    return UnifiedAllocator(AllocatorConfig(
        total_bytes=16 * 1024 ** 3, n_layers=32,
        kv_bytes_per_token=128 * 1024, max_bs=64, qos_s=0.040,
        swap_time_s=0.004, small_pool_bytes=256 * 1024 ** 2))


def test_adapter_reserve_is_all_or_nothing_and_charges_free():
    a = _alloc()
    free0 = a.free_chunks
    assert a.adapter_reserve(4)
    assert a.adapter_chunks == 4 and a.free_chunks == free0 - 4
    # an impossible ask changes nothing
    assert not a.adapter_reserve(a.total_chunks * 2)
    assert a.adapter_chunks == 4 and a.free_chunks == free0 - 4
    a.adapter_release(4)
    assert a.adapter_chunks == 0 and a.free_chunks == free0
    assert a.adapter_leak == 0
    a.check_invariants()


def test_adapter_reserve_reclaims_window_but_never_reserve():
    a = _alloc()
    w0 = a.resize_window(8)              # give finetune a real window
    assert w0 == 8
    headroom = max(a.free_chunks - a.reserved_chunks, 0)
    # ask for more than unreserved headroom: the shortfall must come out
    # of the finetune window, not the reserved QoS headroom
    ask = headroom + 3
    assert a.adapter_reserve(ask)
    assert a.window_chunks == w0 - 3
    assert a.reclaims >= 3
    a.check_invariants()
    # beyond headroom + window is refused outright
    assert not a.adapter_reserve(a.free_chunks + a.window_chunks + 1)
    a.adapter_release(ask)
    assert a.adapter_leak == 0


def test_allocator_conservation_under_adapter_churn():
    """Hot-load/evict storm: every reserve is exactly paired with a
    release, the leak counter stays zero, and invariants hold at every
    step — interleaved with KV traffic on the same allocator."""
    a = _alloc()
    a.resize_window(4)
    live_kv_tokens = 0
    resident = []
    for step in range(200):
        if step % 3 == 0 and a.adapter_reserve(2):
            resident.append(2)
        if step % 7 == 0 and resident:
            a.adapter_release(resident.pop())
        if step % 2 == 0 and a.kv_alloc_tokens(4096):
            live_kv_tokens += 4096
        if step % 5 == 0 and live_kv_tokens >= 4096:
            a.kv_free_tokens(4096)
            live_kv_tokens -= 4096
        a.check_invariants()
    for c in resident:
        a.adapter_release(c)
    a.kv_free_tokens(live_kv_tokens)
    assert a.adapter_chunks == 0
    assert a.adapter_leak == 0
    assert a.adapter_reserved_total == a.adapter_released_total
    a.check_invariants()


# ------------------------------------------------------------- registry --
def test_registry_versions_are_monotone_per_adapter():
    reg = AdapterRegistry()
    assert reg.latest(0) == 0            # unpublished -> base (v0)
    assert reg.publish(0, 1, t=0.0)
    assert reg.publish(0, 2, t=1.0)
    assert not reg.publish(0, 2, t=2.0)  # re-publish is a no-op
    assert not reg.publish(0, 1, t=3.0)  # regression refused
    assert reg.latest(0) == 2 and reg.latest(1) == 0
    assert reg.publish(1, 1, t=4.0)
    assert reg.versions_published == 3
    assert [(aid, v) for (_, aid, v) in reg.published] == \
        [(0, 1), (0, 2), (1, 1)]


# ----------------------------------------------------------- pool churn --
def _pool(max_loaded=0, chunks=2):
    a = _alloc()
    return a, AdapterPool(a, InstanceAdapterConfig(
        chunks=chunks, load_time_s=0.01, max_loaded=max_loaded))


def test_pool_load_evict_storm_leaves_no_leak():
    a, pool = _pool(max_loaded=2)
    for step in range(300):
        aid = step % 5
        ver = 1 + step // 50            # versions advance over the storm
        pool.require(aid, ver)
        dt = pool.take_load_time(in_use=set())
        assert dt >= 0.0
        a.check_invariants()
        assert a.adapter_leak == 0
        assert len(pool.resident) <= 2
    assert pool.loads > 0 and pool.evictions > 0
    pool.evict_all()
    assert a.adapter_chunks == 0 and a.adapter_leak == 0
    a.check_invariants()


def test_pool_version_swap_evicts_old_version_first():
    a, pool = _pool()
    pool.require(7, 1)
    pool.take_load_time(set())
    chunks_v1 = a.adapter_chunks
    pool.require(7, 2)
    pool.take_load_time(set())
    assert pool.resident == {7: 2}      # upgraded, not duplicated
    assert a.adapter_chunks == chunks_v1
    assert pool.evictions == 1 and a.adapter_leak == 0


def test_pool_in_use_adapters_survive_pressure():
    a, pool = _pool(max_loaded=1)
    pool.require(1, 1)
    pool.take_load_time(set())
    pool.require(2, 1)
    # adapter 1 is pinned by an active request: the load of 2 must not
    # evict it, so it fails over to base instead
    pool.take_load_time(in_use={1})
    assert 1 in pool.resident
    assert 2 not in pool.resident
    assert pool.load_failures == 1
    assert a.adapter_leak == 0


def test_adapter_bytes_scales_with_rank():
    b16 = adapter_bytes(LLAMA, 16)
    b32 = adapter_bytes(LLAMA, 32)
    assert b16 > 0 and abs(b32 / b16 - 2.0) < 1e-6


def test_adapter_load_time_deterministic_and_linear():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), seed=11)
    t1 = cm.adapter_load_time(1e9)
    assert t1 == cm.adapter_load_time(1e9)       # no noise term
    assert cm.adapter_load_time(2e9) > t1


# -------------------------------------------- adapters-off bit-identity --
# Pinned from the PR 7 build (commit 9b1b2e4) before any adapter code
# landed: ClusterConfig.adapters=None must not move a single bit in any
# prefill mode.
PIN = {
    "chained": dict(offered=249, routed=249, rejected=0, completed=249,
                    attained=197, goodput=3.286891438,
                    ttft_p99=5.916483059, tpot_p99=0.035959351,
                    ft_iterations=19.227188082, n_decisions=11,
                    final_fleet=1),
    "pooled": dict(offered=249, routed=249, rejected=0, completed=249,
                   attained=249, goodput=4.1544973,
                   ttft_p99=3.205206383, tpot_p99=0.035863741,
                   ft_iterations=18.726256983, n_decisions=22,
                   final_fleet=1),
    "chunked": dict(offered=249, routed=249, rejected=0, completed=249,
                    attained=170, goodput=2.836403779,
                    ttft_p99=10.099020867, tpot_p99=0.037004436,
                    ft_iterations=31.756052142, n_decisions=22,
                    final_fleet=5),
}


@pytest.mark.parametrize("mode", ("chained", "pooled", "chunked"))
def test_adapters_off_bit_identical_to_pr7(mode):
    cluster = ClusterConfig(
        n_initial=2, autoscale=True, prefill_mode=mode,
        prefill=PrefillPoolConfig(n_workers=2) if mode == "pooled"
        else None)
    res = ExperimentSpec(name=f"pin_{mode}", scenario="spike",
                         duration_s=30.0, mean_rps=6.0, seed=3,
                         sim=SimConfig(mode="harli", seed=3),
                         cluster=cluster).run()
    st = res.stats
    got = dict(offered=st.offered, routed=st.routed, rejected=st.rejected,
               completed=st.completed, attained=st.attained,
               goodput=round(st.goodput, 9),
               ttft_p99=round(st.ttft_p99, 9),
               tpot_p99=round(st.tpot_p99, 9),
               ft_iterations=round(res.ft_iterations, 9),
               n_decisions=len(res.decisions),
               final_fleet=res.final_fleet)
    assert got == PIN[mode]
    assert res.adapter_loads == 0 and res.adapter_versions_published == 0


# -------------------------------------------------- end-to-end serving --
def _mt_spec(continuous=True, seed=3, policy="affinity_packed",
             n_tenants=4):
    weights = (0.4, 0.3, 0.2, 0.1)[:n_tenants]
    tenants = tuple(TenantConfig(name=f"t{i}", weight=w)
                    for i, w in enumerate(weights))
    return ExperimentSpec(
        name="mt", scenario="multi_tenant", duration_s=30.0,
        mean_rps=6.0, seed=seed, tenants=tenants,
        sim=SimConfig(mode="harli", seed=seed),
        cluster=ClusterConfig(
            n_initial=2, autoscale=True, prefill_mode="chained",
            prefill=None,
            adapters=AdapterServingConfig(publish_every_iters=1.0,
                                          continuous=continuous,
                                          policy=policy)))


def test_multi_tenant_serving_end_to_end():
    res = _mt_spec().run()
    s = res.stats
    assert s.completed > 0
    assert s.routed + s.rejected == s.offered
    # every tenant got traffic and per-tenant accounting sums to fleet
    assert set(s.tenants) == {0, 1, 2, 3}
    assert sum(t.offered for t in s.tenants.values()) == s.offered
    assert sum(t.completed for t in s.tenants.values()) == s.completed
    # skewed weights show up in the mix
    assert s.tenants[0].offered > s.tenants[3].offered
    # the loop actually closed: versions published, hot-loaded, served
    assert res.adapter_versions_published > 4   # beyond the v1 seeding
    assert res.adapter_loads > 0
    assert res.adapter_load_time_s > 0.0
    assert all(t.versions_served >= 1 for t in s.tenants.values())


def test_multi_tenant_deterministic():
    r1, r2 = _mt_spec().run(), _mt_spec().run()
    assert r1.stats == r2.stats
    assert r1.adapter_loads == r2.adapter_loads
    assert r1.adapter_versions_published == r2.adapter_versions_published


def test_replicate_hot_policy_runs_and_conserves():
    res = _mt_spec(policy="replicate_hot").run()
    s = res.stats
    assert s.routed + s.rejected == s.offered
    assert s.completed > 0 and res.adapter_loads > 0


# ------------------------------------------------- acceptance property --
def test_continuous_deployment_beats_static_baseline():
    """The PR's acceptance pin: harli continuous deployment sustains
    per-tenant TTFT/TPOT SLO attainment >= the static-adapter baseline
    while serving strictly more adapter versions — freshness is free
    because swaps are priced, affinity-placed, and charged against
    headroom the admission path already respects."""
    cont = _mt_spec(continuous=True).run()
    stat = _mt_spec(continuous=False).run()
    # strictly more versions reach production
    assert cont.adapter_versions_published > stat.adapter_versions_published
    assert cont.adapter_versions_served > stat.adapter_versions_served
    # at SLO attainment no worse, fleet-wide and per tenant
    assert cont.stats.attained >= stat.stats.attained
    for tid, tn in cont.stats.tenants.items():
        st = stat.stats.tenants[tid]
        assert tn.ttft_attainment >= st.ttft_attainment - 1e-9
        assert tn.tpot_attainment >= st.tpot_attainment - 1e-9
    # static really is static: exactly one version per tenant
    assert stat.adapter_versions_published == len(stat.stats.tenants)


def test_per_tenant_slo_overrides_flow_into_attainment():
    spec = _mt_spec()
    # tenant 0 gets an impossible TTFT SLO: its attainment must crater
    # while the others (fleet default) are untouched by the override
    tight = dataclasses.replace(spec.tenants[0], ttft_slo_s=1e-6)
    spec = dataclasses.replace(spec,
                               tenants=(tight,) + spec.tenants[1:])
    res = spec.run()
    base = _mt_spec().run()
    assert res.stats.tenants[0].ttft_attainment == 0.0
    assert res.stats.tenants[1].ttft_attainment == \
        base.stats.tenants[1].ttft_attainment


# ------------------------------------------------------------- spec v2 --
def test_spec_v2_round_trip_with_adapters():
    spec = _mt_spec()
    j = spec.to_json()
    assert json.loads(j)["schema_version"] == SCHEMA_VERSION
    rt = ExperimentSpec.from_json(j)
    assert rt == spec
    rt.validate()


def test_spec_v1_upgrades_cleanly_in_one_place():
    v1 = {"name": "old", "scenario": "spike", "duration_s": 10.0,
          "mean_rps": 4.0, "seed": 7}
    up = ExperimentSpec.from_dict(dict(v1))
    assert up.schema_version == SCHEMA_VERSION
    assert up.tenants == () and up.cluster.adapters is None
    up.validate()
    # upgrade_v1 is the single documented migration point
    assert upgrade_v1(dict(v1, schema_version=1)) == v1
    # and a v1 doc behaves exactly like its explicit-v2 rewrite
    assert up == ExperimentSpec.from_dict(dict(v1, schema_version=2))


def test_spec_v1_rejects_smuggled_v2_blocks():
    with pytest.raises(SpecError, match="v2-only"):
        ExperimentSpec.from_dict({"tenants": []})
    with pytest.raises(SpecError, match="cluster.adapters"):
        ExperimentSpec.from_dict(
            {"cluster": {"adapters": {"rank": 8}}})


def test_spec_unknown_version_errors_listing_supported():
    with pytest.raises(SpecError, match=r"supported versions: 1.*2"):
        ExperimentSpec.from_dict({"schema_version": 3})
    with pytest.raises(SpecError, match="unsupported schema_version"):
        ExperimentSpec.from_dict({"schema_version": "two"})


def test_spec_v2_validation_catches_adapter_contradictions():
    # adapters without tenant traffic
    with pytest.raises(SpecError, match="no tenant traffic"):
        ExperimentSpec(cluster=ClusterConfig(
            adapters=AdapterServingConfig())).validate()
    # bad tenant weight
    with pytest.raises(SpecError, match="weight must be > 0"):
        dataclasses.replace(
            _mt_spec(),
            tenants=(TenantConfig(weight=0.0),)).validate()
    # bad SLO override
    with pytest.raises(SpecError, match="ttft_slo_s"):
        dataclasses.replace(
            _mt_spec(),
            tenants=(TenantConfig(ttft_slo_s=-1.0),)).validate()
    # unknown adapter placement policy, scoped to its kind
    bad = _mt_spec()
    bad = dataclasses.replace(bad, cluster=dataclasses.replace(
        bad.cluster, adapters=AdapterServingConfig(policy="nope")))
    with pytest.raises(SpecError, match="adapter_placement"):
        bad.validate()
    # bad publish cadence
    bad2 = _mt_spec()
    bad2 = dataclasses.replace(bad2, cluster=dataclasses.replace(
        bad2.cluster,
        adapters=AdapterServingConfig(publish_every_iters=0.0)))
    with pytest.raises(SpecError, match="publish_every_iters"):
        bad2.validate()


def test_shipped_multi_tenant_spec_validates_and_runs():
    spec = ExperimentSpec.load("examples/specs/multi_tenant_adapters.json")
    spec.validate()
    assert spec.schema_version == SCHEMA_VERSION
    assert spec.cluster.adapters is not None and spec.tenants
    res = dataclasses.replace(spec, duration_s=10.0, mean_rps=4.0).run()
    assert res.stats.completed > 0
