"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus hypothesis-driven paged layouts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # tier-1 container has none
    from _hyp_fallback import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.decode_attention import paged_decode_attention
from repro.kernels.lora_matmul import lora_matmul as lora_kernel
from repro.kernels.ref import (lora_matmul_ref, paged_decode_attention_ref,
                               ssd_sequential_ref)
from repro.models.ssm import ssd_chunked


# ---------------------------------------------------- decode attention ----
@pytest.mark.parametrize("B,H,KV,hd,ptok,npg,dtype", [
    (2, 8, 2, 64, 32, 4, jnp.float32),
    (3, 4, 4, 32, 16, 3, jnp.float32),
    (1, 16, 1, 128, 64, 2, jnp.float32),     # MQA, TPU-aligned head dim
    (2, 8, 2, 64, 32, 4, jnp.bfloat16),
])
def test_paged_decode_attention(B, H, KV, hd, ptok, npg, dtype, key):
    P = npg * B + 2
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (P, ptok, KV, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (P, ptok, KV, hd)).astype(dtype)
    pt = jax.random.permutation(ks[3], P)[:B * npg].reshape(B, npg)
    pt = pt.astype(jnp.int32).at[0, -1].set(-1)
    lengths = jax.random.randint(ks[4], (B,), 1, npg * ptok).astype(jnp.int32)
    out = paged_decode_attention(q, kp, vp, pt, lengths, interpret=True)
    expect = paged_decode_attention_ref(q, kp, vp, pt, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 3), npg=st.integers(1, 4),
       ptok=st.sampled_from([8, 16]), seed=st.integers(0, 2 ** 16))
def test_paged_decode_attention_hypothesis(B, npg, ptok, seed):
    key = jax.random.PRNGKey(seed)
    H, KV, hd = 4, 2, 16
    P = B * npg + 1
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, ptok, KV, hd))
    vp = jax.random.normal(ks[2], (P, ptok, KV, hd))
    pt = jnp.arange(B * npg, dtype=jnp.int32).reshape(B, npg)
    lengths = jax.random.randint(ks[3], (B,), 1, npg * ptok).astype(jnp.int32)
    out = paged_decode_attention(q, kp, vp, pt, lengths, interpret=True)
    expect = paged_decode_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(out, expect, atol=3e-5, rtol=3e-5)


# --------------------------------------------------------- lora matmul ----
@pytest.mark.parametrize("M,K,N,r,dtype", [
    (64, 128, 96, 8, jnp.float32),
    (128, 512, 256, 16, jnp.float32),
    (37, 200, 130, 4, jnp.float32),          # ragged -> padded path
    (128, 256, 128, 16, jnp.bfloat16),
])
def test_lora_matmul(M, K, N, r, dtype, key):
    ks = jax.random.split(key, 4)
    x = (jax.random.normal(ks[0], (M, K)) * 0.1).astype(dtype)
    w = (jax.random.normal(ks[1], (K, N)) * 0.1).astype(dtype)
    a = (jax.random.normal(ks[2], (K, r)) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[3], (r, N)) * 0.1).astype(dtype)
    y = ops.lora_matmul(x, w, a, b, 2.0)
    expect = lora_matmul_ref(x, w, a, b, 2.0)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_lora_matmul_batched_input(key):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (2, 5, 64)) * 0.1
    w = jax.random.normal(ks[1], (64, 48)) * 0.1
    a = jax.random.normal(ks[2], (64, 4)) * 0.1
    b = jax.random.normal(ks[3], (4, 48)) * 0.1
    y = ops.lora_matmul(x, w, a, b, 1.5)
    expect = lora_matmul_ref(x.reshape(10, 64), w, a, b, 1.5).reshape(2, 5, 48)
    np.testing.assert_allclose(y, expect, atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------- ssd scan ----
@pytest.mark.parametrize("B,S,nh,hd,ds,chunk", [
    (2, 32, 8, 16, 32, 8),
    (1, 50, 4, 8, 16, 16),                  # ragged tail chunk
    (2, 64, 16, 32, 64, 32),
])
def test_ssd_scan_kernel(B, S, nh, hd, ds, chunk, key):
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bt = jax.random.normal(ks[3], (B, S, ds)) * 0.3
    Ct = jax.random.normal(ks[4], (B, S, ds)) * 0.3
    y, ht = ops.ssd_scan(xs, dt, A, Bt, Ct, chunk)
    yr, htr = ssd_sequential_ref(xs, dt, A, Bt, Ct)
    np.testing.assert_allclose(y, yr, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(ht, htr, atol=2e-3, rtol=2e-3)


def test_ssd_chunked_ref_matches_sequential(key):
    """The jnp chunked reference itself must equal the recurrence."""
    ks = jax.random.split(key, 5)
    B, S, nh, hd, ds = 2, 40, 4, 8, 16
    xs = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bt = jax.random.normal(ks[3], (B, S, ds)) * 0.3
    Ct = jax.random.normal(ks[4], (B, S, ds)) * 0.3
    y, ht = ssd_chunked(xs, dt, A, Bt, Ct, 8)
    yr, htr = ssd_sequential_ref(xs, dt, A, Bt, Ct)
    np.testing.assert_allclose(y, yr, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(ht, htr, atol=2e-3, rtol=2e-3)


def test_ssd_scan_with_initial_state(key):
    ks = jax.random.split(key, 6)
    B, S, nh, hd, ds = 1, 24, 4, 8, 16
    xs = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bt = jax.random.normal(ks[3], (B, S, ds)) * 0.3
    Ct = jax.random.normal(ks[4], (B, S, ds)) * 0.3
    h0 = jax.random.normal(ks[5], (B, nh, hd, ds)) * 0.2
    y, ht = ops.ssd_scan(xs, dt, A, Bt, Ct, 8, h0=h0)
    yr, htr = ssd_sequential_ref(xs, dt, A, Bt, Ct, h0=h0)
    np.testing.assert_allclose(y, yr, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(ht, htr, atol=2e-3, rtol=2e-3)
