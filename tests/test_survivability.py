"""Survivability layer (live KV migration + overload degradation ladder):
transfer-time arithmetic, migration-credit bookkeeping, seeded determinism,
request conservation with migration in every prefill mode, the knobs-off
bit-identity guarantee, ladder escalation/shedding under overload, spec
validation of contradictory churn combos, and the high-churn acceptance
pin (migration + ladder strictly beats re-prefill-only on goodput at
equal-or-better TPOT p99)."""

import dataclasses
import functools

import pytest

from repro.configs import get_config
from repro.core import api
from repro.core.cluster import (ClusterConfig, DegradationConfig,
                                KVMigrationConfig, simulate_cluster)
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.experiment import ExperimentSpec, SpecError
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.router import RouterConfig
from repro.core.simulator import SimConfig
from repro.serving.request import Request
from repro.serving.trace import (FailureConfig, TraceConfig, generate,
                                 scenario_config)

LLAMA = get_config("llama3-8b")

# long-context / long-output trace: requests live long enough for kills to
# catch them mid-decode and re-prefill is expensive enough for live
# migration to matter
LONG_TRACE = TraceConfig(duration_s=90.0, mean_rps=8.0, burstiness=0.8,
                         rate_amplitude=0.1, prompt_median=2048,
                         output_median=512, output_max=1024, seed=1)
CHURN = FailureConfig(rate_per_min=10.0, warning_s=5.0,
                      checkpoint_interval_s=15.0, seed=9)
MODE_KW = {
    "chained": dict(prefill_mode="chained", prefill=None),
    "pooled": dict(prefill_mode="pooled", prefill=PrefillPoolConfig()),
    "chunked": dict(prefill_mode="chunked", prefill=None),
}


def _run(mig=None, deg=None, failures=CHURN, mode="pooled", n=3, seed=2,
         trace=LONG_TRACE, autoscale=True):
    return simulate_cluster(
        LLAMA, LLAMA, generate(trace), SimConfig(mode="harli", seed=seed),
        ClusterConfig(n_initial=n, autoscale=autoscale,
                      router=RouterConfig(), failures=failures,
                      migration=mig, degradation=deg, **MODE_KW[mode]))


@functools.lru_cache(maxsize=None)
def _acceptance(arm: str):
    """The three acceptance arms, cached across tests."""
    if arm == "reprefill":
        return _run()
    if arm == "migrate":
        return _run(mig=KVMigrationConfig())
    return _run(mig=KVMigrationConfig(), deg=DegradationConfig())


# ------------------------------------------------------ cost model ----
def test_kv_migration_time_arithmetic():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), seed=0)
    bw = 8e9
    t = cm.kv_migration_time(1024, bw, setup_s=0.005)
    expect = 0.005 + (1024 * LLAMA.cache_bytes_per_token()
                      + LLAMA.state_bytes()) / bw
    assert t == pytest.approx(expect)
    # deterministic: no RNG draw, so repeat calls are bit-identical
    assert cm.kv_migration_time(1024, bw) == cm.kv_migration_time(1024, bw)
    # a dead link degenerates to the 1 B/s floor, never divides by zero
    assert cm.kv_migration_time(1, 0.0) > 0


def test_migrated_tokens_shorten_effective_prefill():
    r = Request(rid=0, arrival=0.0, prompt_len=1000, max_new_tokens=10)
    assert r.effective_prompt_len == 1000
    r.migrated_tokens = 400
    assert r.effective_prompt_len == 600
    r.cache_hit_tokens = 300
    assert r.effective_prompt_len == 300
    r.reset_for_retry()              # the credit dies with a later failure
    assert r.migrated_tokens == 0 and r.effective_prompt_len == 1000
    assert r.restarts == 1


def test_migration_policies_registered():
    assert "migration" in api.KINDS
    for name in ("kv_headroom", "least_loaded"):
        cls = api.resolve_policy("migration", name)
        assert issubclass(cls, api.MigrationPolicy)


# ---------------------------------------------------- determinism ----
def test_migration_deterministic_across_reruns():
    a = _acceptance("migrate")
    b = _run(mig=KVMigrationConfig())
    assert a.stats == b.stats
    assert a.migrated_requests == b.migrated_requests
    assert a.migrated_kv_tokens == b.migrated_kv_tokens
    assert a.migration_reprefills == b.migration_reprefills
    assert a.fleet_timeline == b.fleet_timeline


def test_migration_seed_sensitive():
    a = _acceptance("migrate")
    c = _run(mig=KVMigrationConfig(), seed=3)
    assert a.stats != c.stats


# --------------------------------------- conservation in every mode ----
@pytest.mark.parametrize("mode", sorted(MODE_KW))
def test_migration_conserves_requests(mode):
    trace = dataclasses.replace(LONG_TRACE, duration_s=60.0)
    res = _run(mig=KVMigrationConfig(), mode=mode, trace=trace)
    # simulate_cluster runs ClusterRouter.check_conservation internally;
    # reaching here means every request is rejected xor staged xor placed
    # exactly once even after live moves across instances
    assert res.migrated_requests > 0
    assert res.stats.offered == res.stats.routed + res.stats.rejected


def test_partial_tail_on_slow_link():
    # ~10 MB/s can rarely ship a median 2k-token context inside the 5 s
    # warning: almost every transfer loses the race, and the losing head
    # still ships a partial tail that shortens its re-prefill
    trace = dataclasses.replace(LONG_TRACE, duration_s=60.0)
    slow = _run(mig=KVMigrationConfig(bw_gbps=0.01), trace=trace)
    fast = _run(mig=KVMigrationConfig(), trace=trace)
    assert slow.migrated_requests < fast.migrated_requests
    assert slow.migration_reprefills > 0
    assert slow.migrated_kv_tokens > 0         # partial tails still shipped


# ------------------------------------------------ knobs-off identity ----
def test_bw_zero_bit_identical_to_no_migration():
    trace = dataclasses.replace(LONG_TRACE, duration_s=60.0)
    a = _run(trace=trace)
    b = _run(mig=KVMigrationConfig(bw_gbps=0.0), trace=trace)
    assert a.stats == b.stats
    assert a.fleet_timeline == b.fleet_timeline
    assert [d.action for d in a.decisions] == [d.action for d in b.decisions]
    assert a.ft_throughput == b.ft_throughput
    assert b.migrated_requests == 0 and b.migrated_kv_tokens == 0


def test_unreachable_ladder_bit_identical_to_no_ladder():
    trace = dataclasses.replace(LONG_TRACE, duration_s=60.0)
    a = _run(trace=trace)
    b = _run(deg=DegradationConfig(breaker_viol_frac=2.0,
                                   shed_viol_frac=2.0,
                                   resume_viol_frac=0.0), trace=trace)
    assert a.stats == b.stats
    assert a.fleet_timeline == b.fleet_timeline
    assert b.ladder_peak == 0 and b.shed_requests == 0
    assert b.breaker_epochs == 0 and b.shed_epochs == 0


# ------------------------------------------------- degradation ladder ----
# bursty spikes on a pinned two-instance fleet: TTFT misses pile up
# mid-run, so the ladder escalates while arrivals are still flowing
OVERLOAD = scenario_config("spike", 60.0, 20.0, seed=1)
EAGER = DegradationConfig(breaker_viol_frac=0.2, shed_viol_frac=0.4,
                          resume_viol_frac=0.05)


@functools.lru_cache(maxsize=None)
def _overload(with_ladder: bool):
    deg = EAGER if with_ladder else None
    return _run(deg=deg, failures=None, mode="pooled", n=2,
                trace=OVERLOAD, autoscale=False)


def test_ladder_escalates_and_sheds_under_overload():
    res = _overload(True)
    assert res.ladder_peak == 2
    assert res.breaker_epochs > 0
    assert res.shed_epochs > 0
    assert res.shed_requests > 0
    assert res.shed_rejected > 0
    # hard-rejected shed requests are terminal rejects, attributed in both
    # the ladder counter and the router's reject accounting
    assert res.stats.shed_rejected == res.shed_rejected
    assert res.stats.rejected >= res.shed_rejected
    # escalation is ordered: shedding only happens while the breaker holds
    assert res.breaker_epochs >= res.shed_epochs


def test_breaker_stalls_colocated_finetune():
    assert _overload(True).ft_stall_rounds > _overload(False).ft_stall_rounds


def test_shed_backoff_deterministic_and_seed_isolated():
    a = _overload(True)
    b = _run(deg=EAGER, failures=None, mode="pooled", n=2,
             trace=OVERLOAD, autoscale=False)
    assert a.stats == b.stats and a.shed_requests == b.shed_requests
    # an explicit backoff seed is honored without touching the sim streams
    c = _run(deg=dataclasses.replace(EAGER, seed=123), failures=None,
             mode="pooled", n=2, trace=OVERLOAD, autoscale=False)
    d = _run(deg=dataclasses.replace(EAGER, seed=123), failures=None,
             mode="pooled", n=2, trace=OVERLOAD, autoscale=False)
    assert c.stats == d.stats


# -------------------------------------------------- acceptance pin ----
def test_migration_beats_reprefill_at_high_churn():
    """The PR's headline regression pin: at high churn (10 kills/min,
    5 s warnings, long contexts) live migration strictly improves
    goodput over the PR 6 re-prefill-only path, the full ladder on top
    improves it further, and TPOT p99 never degrades."""
    base = _acceptance("reprefill")
    mig = _acceptance("migrate")
    full = _acceptance("full")
    assert mig.migrated_requests > 0 and mig.migration_reprefills > 0
    assert mig.stats.goodput > base.stats.goodput
    assert full.stats.goodput > mig.stats.goodput
    assert mig.stats.tpot_p99 <= base.stats.tpot_p99 + 1e-9
    assert full.stats.tpot_p99 <= base.stats.tpot_p99 + 1e-9
    # the ladder engaged (breaker epochs) rather than winning by accident
    assert full.breaker_epochs > 0


# ------------------------------------------------- spec validation ----
def _spec(**cluster_kw):
    cl = ClusterConfig(n_initial=2, prefill_mode="pooled",
                       prefill=PrefillPoolConfig(),
                       failures=FailureConfig(rate_per_min=2.0,
                                              warning_s=5.0,
                                              checkpoint_interval_s=15.0,
                                              seed=7))
    for k, v in cluster_kw.items():
        setattr(cl, k, v)
    return ExperimentSpec(name="t", inf_model="llama3-8b",
                          ft_model="llama3-8b", scenario="steady",
                          duration_s=10.0, mean_rps=2.0, seed=0,
                          sim=SimConfig(mode="harli", seed=1), cluster=cl)


def test_validate_accepts_survivability_spec():
    _spec(migration=KVMigrationConfig(),
          degradation=DegradationConfig()).validate()


@pytest.mark.parametrize("cluster_kw,match", [
    (dict(migration=KVMigrationConfig(), failures=None),
     "failures is null"),
    (dict(migration=KVMigrationConfig(),
          failures=FailureConfig(rate_per_min=2.0, warning_s=0.0,
                                 checkpoint_interval_s=15.0, seed=7)),
     "warning_s is 0"),
    (dict(migration=KVMigrationConfig(bw_gbps=0.0)), "bw_gbps must be > 0"),
    (dict(migration=KVMigrationConfig(setup_s=-1.0)), "setup_s"),
    (dict(migration=KVMigrationConfig(policy="nope")), "nope"),
    (dict(degradation=DegradationConfig(breaker_viol_frac=0.8,
                                        shed_viol_frac=0.5)),
     "escalates through them in order"),
    (dict(degradation=DegradationConfig(resume_viol_frac=0.5,
                                        breaker_viol_frac=0.4)),
     "escalates through them in order"),
    (dict(degradation=DegradationConfig(backoff_mult=0.5)),
     "backoff knobs out of range"),
    (dict(degradation=DegradationConfig(backoff_jitter=1.0)),
     "backoff knobs out of range"),
    (dict(degradation=DegradationConfig(max_retries=-1)),
     "backoff knobs out of range"),
    (dict(degradation=DegradationConfig(shed=False, max_retries=5)),
     "shed is false"),
    (dict(degradation=DegradationConfig(shed=False, backoff_base_s=2.0)),
     "shed is false"),
])
def test_validate_rejects_contradictory_churn_combos(cluster_kw, match):
    with pytest.raises(SpecError, match=match):
        _spec(**cluster_kw).validate()


def test_spec_roundtrip_preserves_survivability_blocks():
    spec = _spec(migration=KVMigrationConfig(bw_gbps=4.0, policy="least_loaded"),
                 degradation=DegradationConfig(max_retries=5))
    again = ExperimentSpec.from_json(spec.to_json())
    assert again.cluster.migration == spec.cluster.migration
    assert again.cluster.degradation == spec.cluster.degradation
    again.validate()
