"""Unified memory allocator + buddy pool invariants (hypothesis-driven;
falls back to seeded random sequences when hypothesis is not installed)."""

import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # tier-1 container has none
    from _hyp_fallback import given, settings, strategies as st

from repro.core.allocator import AllocatorConfig, UnifiedAllocator
from repro.core.buddy import BuddyAllocator


# ----------------------------------------------------------------- buddy --
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                          st.integers(1, 64 * 2048)), min_size=1,
                max_size=120))
def test_buddy_invariants(ops):
    b = BuddyAllocator(256 * 2048)
    live = []
    for op, size in ops:
        if op == "alloc":
            off = b.alloc(size)
            if off is not None:
                # no overlap with any live block
                lvl = b.allocated[off]
                end = off + b.block_size(lvl)
                for o2 in live:
                    l2 = b.allocated[o2]
                    e2 = o2 + b.block_size(l2)
                    assert end <= o2 or e2 <= off, "overlap"
                live.append(off)
        elif live:
            b.freeb(live.pop())
        b.check_invariants()
    for off in live:
        b.freeb(off)
    b.check_invariants()
    assert b.allocated_bytes == 0
    # fully coalesced back to a single block
    assert b.fragmentation_bytes == 0


def test_buddy_exhaustion_and_reuse():
    b = BuddyAllocator(8 * 2048)
    offs = [b.alloc(2048) for _ in range(8)]
    assert all(o is not None for o in offs)
    assert b.alloc(1) is None
    b.freeb(offs[3])
    assert b.alloc(2048) is not None


# --------------------------------------------------------------- unified --
def _alloc(total_gb=16, layers=32, kv=128 * 1024, swap=0.004):
    return UnifiedAllocator(AllocatorConfig(
        total_bytes=total_gb * 1024 ** 3, n_layers=layers,
        kv_bytes_per_token=kv, max_bs=64, qos_s=0.040, swap_time_s=swap,
        small_pool_bytes=256 * 1024 ** 2))


def test_reserved_headroom_formula():
    a = _alloc()
    # Mem_reserved = (T/QoS) * max_bs * Mem_kv  (paper §4.4)
    tokens = math.ceil(0.004 / 0.040 * 64)
    expect = max(math.ceil(tokens * 128 * 1024 / a.chunk_bytes), 1)
    assert a.reserved_chunks == expect


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["kv+", "kv-", "win"]),
                          st.integers(1, 40_000)), min_size=1, max_size=80))
def test_unified_invariants(ops):
    a = _alloc()
    for op, n in ops:
        if op == "kv+":
            a.kv_alloc_tokens(n)
        elif op == "kv-":
            a.kv_free_tokens(n)
        else:
            a.resize_window(n % (a.total_chunks + 1))
        a.check_invariants()
        # budget conservation
        assert a.kv_chunks + a.window_chunks + a.free_chunks \
            == a.total_chunks
        # window never eats the reserve
        assert a.window_chunks <= max(
            a.total_chunks - a.kv_chunks - 0, a.total_chunks)


def test_kv_pressure_reclaims_window():
    a = _alloc()
    a.resize_window(a.window_capacity_chunks())
    w0 = a.window_chunks
    assert w0 > 0
    # fill KV beyond free space: the window must be reclaimed, not fail
    tokens = (a.free_chunks + w0 // 2) * a.tokens_per_chunk
    assert a.kv_alloc_tokens(tokens)
    assert a.window_chunks < w0
    assert a.reclaims > 0
    a.check_invariants()


def test_kv_alloc_fails_only_when_oom():
    a = _alloc()
    total_tokens = a.total_chunks * a.tokens_per_chunk
    assert a.kv_alloc_tokens(total_tokens)       # fill everything
    assert not a.kv_alloc_tokens(a.tokens_per_chunk + 1)
    a.kv_free_tokens(2 * a.tokens_per_chunk)
    assert a.kv_alloc_tokens(a.tokens_per_chunk)


def test_window_capacity_respects_reserve():
    a = _alloc()
    cap = a.window_capacity_chunks()
    assert cap == a.total_chunks - a.reserved_chunks
    a.kv_alloc_tokens(10 * a.tokens_per_chunk)
    assert a.window_capacity_chunks() == \
        a.total_chunks - a.kv_chunks - a.reserved_chunks
