"""Flash attention (chunked online softmax) vs the dense oracle, and
prefill/decode cache-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import attention as A
from repro.models.config import ModelConfig


@pytest.mark.parametrize("Sq,Sk,H,KV,hd,causal,window,cap", [
    (17, 17, 4, 2, 16, True, 0, 0.0),
    (33, 33, 8, 8, 32, True, 0, 0.0),
    (16, 48, 4, 1, 16, True, 0, 0.0),      # MQA, decode-chunk offset
    (40, 40, 4, 4, 16, True, 8, 0.0),      # sliding window
    (24, 24, 4, 2, 16, False, 0, 0.0),     # bidirectional (encoder)
    (24, 24, 4, 2, 16, True, 0, 30.0),     # logit soft cap
])
def test_flash_vs_dense(Sq, Sk, H, KV, hd, causal, window, cap, key):
    ks = jax.random.split(key, 3)
    B = 2
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), jnp.float32)
    out = L.flash_attention(q, k, v, causal=causal, window=window,
                            soft_cap=cap, q_chunk=8, kv_chunk=16)
    ref = L.attention_ref(q, k, v, causal=causal, window=window,
                          soft_cap=cap)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_mla_value_dim(key):
    """MLA uses different q/k and v head dims."""
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 12, 4, 24))
    k = jax.random.normal(ks[1], (2, 12, 4, 24))
    v = jax.random.normal(ks[2], (2, 12, 4, 16))
    out = L.flash_attention(q, k, v, q_chunk=4, kv_chunk=8)
    ref = L.attention_ref(q, k, v)
    assert out.shape == (2, 12, 4, 16)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def _decode_matches_forward(cfg, key, extra=None):
    """Greedy decode must produce the same logits as teacher-forced forward."""
    from repro.models import model as MD
    params = MD.init_params(cfg, key)
    B, S = 2, 10
    tokens = jax.random.randint(key, (B, S + 4), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if extra:
        batch.update(extra)
    full_logits, _ = MD.forward(params, cfg, batch)

    cache = MD.init_cache(cfg, B, S + 8,
                          enc_len=extra["enc_frames"].shape[1]
                          if extra and "enc_frames" in extra else 0)
    pre = {"tokens": tokens[:, :S]}
    if extra:
        pre.update(extra)
    last, cache = MD.prefill(params, cfg, pre, cache)
    off = 0
    if extra and "frontend" in extra:
        off = extra["frontend"].shape[1]
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(full_logits[:, S - 1], np.float32), atol=5e-2, rtol=5e-2)
    # two decode steps tracking the teacher-forced sequence
    for t in range(S, S + 2):
        pos = jnp.full((B,), t + off, jnp.int32)
        logits, cache = MD.decode_step(params, cfg, tokens[:, t], pos, cache)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32), atol=5e-2, rtol=5e-2)


def test_decode_consistency_dense(key):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=64,
                      qk_norm=True)
    _decode_matches_forward(cfg, key)


def test_decode_consistency_swa(key):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=64,
                      attn_type="swa", window=6)
    _decode_matches_forward(cfg, key)


def test_decode_consistency_mla(key):
    # MoE capacity drops make full-seq vs per-token dispatch diverge by
    # design, so the MLA consistency check runs with a dense FFN; MoE
    # routing determinism is covered in test_models.py.
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=96, vocab_size=64,
                      mla=True, mla_q_rank=32, mla_kv_rank=16,
                      mla_rope_dim=8, mla_nope_dim=16, mla_v_dim=16)
    _decode_matches_forward(cfg, key)


def test_decode_consistency_ssm(key):
    cfg = ModelConfig(name="t", family="ssm", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=4)
    _decode_matches_forward(cfg, key)


def test_decode_consistency_hybrid(key):
    cfg = ModelConfig(name="t", family="hybrid", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=1, d_ff=96, vocab_size=64,
                      hybrid_pattern="rra", local_window=8)
    _decode_matches_forward(cfg, key)


def test_int8_kv_cache_accuracy(key):
    """int8 KV with folded per-token scales: decode logits within 5% of the
    bf16 cache (full and SWA-ring layouts)."""
    import dataclasses
    from repro.models import model as MD
    for window in (0, 6):
        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=64,
                          attn_type="swa" if window else "full",
                          window=window)
        params = MD.init_params(cfg, key)
        B, S = 2, 10
        tokens = jax.random.randint(key, (B, S + 3), 0, 64)
        outs = {}
        for tag, c in (("bf16", cfg),
                       ("int8", dataclasses.replace(cfg, kv_quant=True))):
            cache = MD.init_cache(c, B, S + 8)
            last, cache = MD.prefill(params, c, {"tokens": tokens[:, :S]},
                                     cache)
            lg, _ = MD.decode_step(params, c, tokens[:, S],
                                   jnp.full((B,), S, jnp.int32), cache)
            outs[tag] = np.asarray(lg, np.float32)
        rel = np.max(np.abs(outs["bf16"] - outs["int8"])) \
            / np.max(np.abs(outs["bf16"]))
        assert rel < 0.05, (window, rel)
