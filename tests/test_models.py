"""Per-architecture smoke tests: every assigned arch (reduced config) runs
one forward, one PEFT train step, and one decode step on CPU with shape and
finiteness asserts. Full configs are exercised only by the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config, smoke_config
from repro.models import model as MD
from repro.training import peft as P
from repro.training.optimizer import AdamWConfig, adamw_init

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


def _batch(cfg, key, B=2, S=12):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        b["frontend"] = jax.random.normal(key, (B, cfg.frontend_tokens,
                                                cfg.d_model))
    if cfg.enc_layers:
        b["enc_frames"] = jax.random.normal(key, (B, 6, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ALL)
def test_arch_smoke(arch, key):
    cfg = smoke_config(arch)
    params = MD.init_params(cfg, key)
    adapters = MD.init_adapters(cfg, key)
    batch = _batch(cfg, key)
    B, S = batch["tokens"].shape

    # forward
    logits, aux = jax.jit(
        lambda p, a, b: MD.forward(p, cfg, b, adapters=a))(
        params, adapters, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch

    # one PEFT train step (adapters-only grads)
    step = jax.jit(P.make_train_step(cfg, AdamWConfig(lr=1e-3), remat=True))
    ad2, opt2, metrics = step(params, adapters, adamw_init(adapters), batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # adapters must actually move
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(adapters),
                                jax.tree.leaves(ad2)))
    assert delta > 0, f"{arch}: adapters did not update"

    # prefill + decode step
    enc_len = batch["enc_frames"].shape[1] if "enc_frames" in batch else 0
    front = batch["frontend"].shape[1] if "frontend" in batch else 0
    cache = MD.init_cache(cfg, B, S + front + 4, enc_len=enc_len)
    last, cache = jax.jit(lambda p, b, c: MD.prefill(p, cfg, b, c))(
        params, {k: v for k, v in batch.items() if k != "labels"}, cache)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    pos = jnp.full((B,), S + front, jnp.int32)
    lg, cache = jax.jit(lambda p, t, q, c: MD.decode_step(p, cfg, t, q, c))(
        params, tok, pos, cache)
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ALL)
def test_param_count_formula(arch, key):
    """Analytic param_count must match actual initialization exactly."""
    cfg = smoke_config(arch)
    params = MD.init_params(cfg, key)
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    # formula excludes the MTP head (extra trunk) — subtract it when present
    if cfg.mtp and "mtp" in params:
        actual -= sum(int(np.prod(x.shape))
                      for x in jax.tree.leaves(params["mtp"]))
    expected = cfg.param_count()
    assert abs(actual - expected) / max(expected, 1) < 0.02, \
        f"{arch}: init {actual} vs formula {expected}"


def test_moe_routing_deterministic(key):
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                      moe=True, num_experts=4, top_k=2, moe_d_ff=32)
    params = MD.init_params(cfg, key)
    b = _batch(cfg, key)
    l1, _ = MD.forward(params, cfg, b)
    l2, _ = MD.forward(params, cfg, b)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_moe_no_drop_high_capacity(key):
    from repro.models import moe as M
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                      moe=True, num_experts=4, top_k=2, moe_d_ff=32,
                      capacity_factor=4.0)
    p = M.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 16, 32), jnp.float32)
    y, aux = M.moe_forward(p, x, cfg)
    assert float(aux["dropped_frac"]) == 0.0
    assert y.shape == x.shape


def test_lora_zero_init_is_identity(key):
    """B=0 at init: adapters must not change the forward pass."""
    cfg = smoke_config("qwen3-8b")
    params = MD.init_params(cfg, key)
    adapters = MD.init_adapters(cfg, key)
    batch = _batch(cfg, key)
    l0, _ = MD.forward(params, cfg, batch, adapters=None)
    l1, _ = MD.forward(params, cfg, batch, adapters=adapters)
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), atol=1e-6)
