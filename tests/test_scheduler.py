"""QoS-scheduler invariants (paper §6): the quantum never predicts past the
QoS budget, the safety margin adapts downward on violations but is floored,
and idle rounds free-run the finetune job."""

import pytest

from repro.configs import get_config
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.predictor import TwoStageLatencyPredictor
from repro.core.scheduler import QoSScheduler, SchedulerConfig

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp_fallback import given, settings, strategies as st


@pytest.fixture(scope="module")
def predictor():
    pred = TwoStageLatencyPredictor(k_max=10)
    cm = CostModel(get_config("llama3-8b"), InstanceSpec(tp=2), seed=5)
    pred.fit_from_costmodel(cm)
    return pred


def _sched(predictor, **kw):
    return QoSScheduler(predictor, SchedulerConfig(**kw))


# ---------------------------------------------------------- pick() bound --
@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 60), st.integers(64, 4096),
                          st.integers(1, 10)), min_size=1, max_size=50))
def test_pick_never_exceeds_budget(predictor, rounds):
    """Whatever the load, pick() only returns k > 0 when the *predicted*
    co-located latency fits inside qos_s x margin."""
    s = _sched(predictor)
    for bs, ctx, avail in rounds:
        d = s.pick(bs, float(ctx), ft_ready=True, ft_units_available=avail)
        assert 0 <= d.k <= min(s.cfg.k_max, avail)
        if d.k > 0:
            assert d.predicted_s <= s.cfg.qos_s * s.margin + 1e-12, \
                (d.k, d.predicted_s, s.margin)
            assert d.reason == "ok"
        else:
            assert d.reason in ("qos", "stalled")


def test_pick_zero_when_stalled(predictor):
    s = _sched(predictor)
    d = s.pick(8, 512.0, ft_ready=False, ft_units_available=0)
    assert d.k == 0 and d.reason == "stalled"
    d = s.pick(8, 512.0, ft_ready=True, ft_units_available=0)
    assert d.k == 0 and d.reason == "stalled"


def test_idle_rounds_free_run(predictor):
    """bs == 0: the finetune quantum takes every available unit."""
    s = _sched(predictor)
    d = s.pick(0, 0.0, ft_ready=True, ft_units_available=10)
    assert d.k == s.cfg.k_max and d.reason == "idle"
    d = s.pick(0, 0.0, ft_ready=True, ft_units_available=3)
    assert d.k == 3 and d.reason == "idle"
    d = s.pick(0, 0.0, ft_ready=False, ft_units_available=0)
    assert d.k == 0


# ------------------------------------------------------- margin feedback --
def test_margin_shrinks_on_violations_with_floor(predictor):
    s = _sched(predictor)
    m0 = s.margin
    s.observe(s.cfg.qos_s * 1.5)
    assert s.margin == pytest.approx(m0 - s.cfg.margin_adapt)
    for _ in range(100):
        s.observe(s.cfg.qos_s * 1.5)
    assert s.margin == pytest.approx(s.cfg.margin_floor)
    assert s.violations == 101


def test_margin_recovers_slowly_and_caps_at_safety(predictor):
    s = _sched(predictor)
    for _ in range(5):
        s.observe(s.cfg.qos_s * 2.0)
    lo = s.margin
    assert lo < s.cfg.safety
    for _ in range(1000):
        s.observe(s.cfg.qos_s * 0.5)        # well under budget
    assert lo < s.margin <= s.cfg.safety + 1e-12


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.0, 0.2), min_size=1, max_size=200))
def test_margin_always_within_bounds(predictor, latencies):
    s = _sched(predictor)
    for lat in latencies:
        s.observe(lat)
        assert s.cfg.margin_floor - 1e-12 <= s.margin \
            <= s.cfg.safety + 1e-12


def test_tighter_margin_never_picks_larger_quantum(predictor):
    """Monotonicity: after violations shrink the margin, the chosen k at a
    fixed operating point can only stay equal or decrease."""
    s_fresh = _sched(predictor)
    s_burnt = _sched(predictor)
    for _ in range(6):
        s_burnt.observe(s_burnt.cfg.qos_s * 2.0)
    for bs, ctx in ((4, 256.0), (12, 1024.0), (24, 2048.0), (48, 4096.0)):
        k_fresh = s_fresh.pick(bs, ctx, ft_ready=True,
                               ft_units_available=10).k
        k_burnt = s_burnt.pick(bs, ctx, ft_ready=True,
                               ft_units_available=10).k
        assert k_burnt <= k_fresh, (bs, ctx, k_burnt, k_fresh)
