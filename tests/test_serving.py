"""Serving substrate: engine continuous batching, page-table manager,
trace generator statistics."""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # tier-1 container has none
    from _hyp_fallback import given, settings, strategies as st

from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagePoolSpec, PageTableManager
from repro.serving.request import Request
from repro.serving.trace import TraceConfig, controlled_load, generate


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=256)
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_continuous_batching(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_slots=4, s_max=64)
    reqs = [Request(rid=i, arrival=i * 0.01, prompt_len=8 + i,
                    max_new_tokens=6) for i in range(6)]
    m = eng.run_trace(reqs)
    assert m.prefills == 6
    assert m.tokens_out == 6 * 6
    assert max(m.round_batch_sizes) == 4        # slots saturate
    assert all(r.phase.value == "done" for r in reqs)


def test_engine_memory_pressure_rejects(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, max_slots=4, s_max=64, num_pages=4,
                        page_tokens=16)
    r = Request(rid=0, arrival=0.0, prompt_len=60, max_new_tokens=4)
    ok = eng.try_admit(r, np.arange(60, dtype=np.int32) % 256)
    assert ok
    r2 = Request(rid=1, arrival=0.0, prompt_len=60, max_new_tokens=4)
    assert not eng.try_admit(r2, np.arange(60, dtype=np.int32) % 256)


# ------------------------------------------------------- page tables ------
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["admit", "extend", "release"]),
                          st.integers(0, 7), st.integers(1, 40)),
                min_size=1, max_size=60))
def test_page_table_invariants(ops):
    spec = PagePoolSpec(n_layers=2, num_pages=32, page_tokens=8,
                        kv_heads=2, head_dim=16)
    mgr = PageTableManager(spec, max_slots=8, max_pages_per_seq=8)
    for op, slot, n in ops:
        if op == "admit" and slot not in mgr.tables:
            mgr.admit(slot, n)
        elif op == "extend" and slot in mgr.tables:
            mgr.extend(slot, n)
        elif op == "release":
            mgr.release(slot)
        # no page owned twice
        owned = [p for pages in mgr.tables.values() for p in pages]
        assert len(owned) == len(set(owned))
        assert len(owned) + len(mgr.free) == spec.num_pages
        for s, pages in mgr.tables.items():
            assert len(pages) >= -(-mgr.lengths[s] // spec.page_tokens)


def test_page_table_usable_cap():
    spec = PagePoolSpec(n_layers=2, num_pages=16, page_tokens=8,
                        kv_heads=2, head_dim=16)
    mgr = PageTableManager(spec, 4, 8)
    mgr.set_usable(2)                 # allocator lent the rest to finetune
    assert mgr.admit(0, 16)
    assert not mgr.admit(1, 8)        # over the usable cap
    mgr.set_usable(16)
    assert mgr.admit(1, 8)


# ------------------------------------------------------------- traces -----
def test_trace_statistics():
    reqs = generate(TraceConfig(duration_s=600, mean_rps=5.3, seed=0))
    n = len(reqs)
    assert 0.6 * 5.3 * 600 < n < 1.6 * 5.3 * 600
    prompts = np.array([r.prompt_len for r in reqs])
    outs = np.array([r.max_new_tokens for r in reqs])
    assert 500 < np.median(prompts) < 2000       # lognormal around 1024
    assert 60 < np.median(outs) < 300
    arr = np.diff([r.arrival for r in reqs])
    assert np.std(arr) > np.mean(arr)            # burstier than Poisson


def test_trace_deterministic():
    a = generate(TraceConfig(duration_s=60, seed=7))
    b = generate(TraceConfig(duration_s=60, seed=7))
    assert [(r.arrival, r.prompt_len) for r in a] == \
        [(r.arrival, r.prompt_len) for r in b]


def test_controlled_load_phases():
    reqs = controlled_load(phases=((8, 30.0), (42, 30.0)), output_len=200)
    t = np.array([r.arrival for r in reqs])
    early = ((t >= 5) & (t < 30)).sum() / 25.0
    late = ((t >= 35) & (t < 60)).sum() / 25.0
    assert late > 3 * early                      # heavy phase is heavier


def test_paged_pool_roundtrip_matches_dense(key):
    """paged_write + the Pallas paged kernel reproduce dense decode
    attention through a page-table indirection."""
    import jax.numpy as jnp
    from repro.kernels.ops import paged_decode_attention
    from repro.models.attention import decode_attn_ref
    from repro.serving.kv_cache import PagePoolSpec, PageTableManager, \
        paged_write

    spec = PagePoolSpec(n_layers=1, num_pages=12, page_tokens=8,
                        kv_heads=2, head_dim=16, dtype=jnp.float32)
    pool = spec.alloc()
    mgr = PageTableManager(spec, max_slots=3, max_pages_per_seq=4)
    lengths = [11, 19, 5]
    for slot, ln in enumerate(lengths):
        assert mgr.admit(slot, ln)
    table = jnp.asarray(mgr.table_array([0, 1, 2]))

    ks = jax.random.split(key, 2 * max(lengths))
    dense_k = np.zeros((3, 32, 2, 16), np.float32)
    dense_v = np.zeros((3, 32, 2, 16), np.float32)
    for pos in range(max(lengths)):
        kn = jax.random.normal(ks[2 * pos], (3, 2, 16))
        vn = jax.random.normal(ks[2 * pos + 1], (3, 2, 16))
        # clamp inactive slots to their last valid position; their writes
        # are overwritten by nothing (position already written) but the
        # final pass below only trusts positions < length
        positions = jnp.asarray([min(pos, ln - 1) for ln in lengths],
                                jnp.int32)
        pool = paged_write(pool, table, 0, positions, kn, vn)
        for s_ in range(3):
            p_ = min(pos, lengths[s_] - 1)
            dense_k[s_, p_] = np.asarray(kn[s_])
            dense_v[s_, p_] = np.asarray(vn[s_])

    q = jax.random.normal(key, (3, 4, 16))
    lens = jnp.asarray(lengths, jnp.int32)
    out = paged_decode_attention(q, pool[0, 0], pool[0, 1], table, lens)

    kv_pos = np.full((3, 32), -1, np.int32)
    for s_, ln in enumerate(lengths):
        kv_pos[s_, :ln] = np.arange(ln)
    ref = decode_attn_ref(q, jnp.asarray(dense_k), jnp.asarray(dense_v),
                          jnp.asarray(kv_pos),
                          jnp.asarray([ln - 1 for ln in lengths], jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)
