"""Trace generator: per-seed determinism, length caps, and the intended
load shapes of the multi-tenant scenario presets."""

import numpy as np
import pytest

from repro.serving.trace import (SCENARIOS, TraceConfig, controlled_load,
                                 generate, generate_scenario, peak_rps,
                                 scenario_config)


def _sig(reqs):
    return [(r.rid, round(r.arrival, 9), r.prompt_len, r.max_new_tokens)
            for r in reqs]


# ------------------------------------------------------------ generator --
def test_determinism_per_seed():
    cfg = TraceConfig(duration_s=120.0, seed=7)
    assert _sig(generate(cfg)) == _sig(generate(cfg))
    assert _sig(generate(cfg)) != _sig(generate(
        TraceConfig(duration_s=120.0, seed=8)))


def test_caps_and_positivity():
    cfg = TraceConfig(duration_s=300.0, prompt_max=2048, output_max=256,
                      prompt_sigma=2.0, output_sigma=2.0, seed=3)
    reqs = generate(cfg)
    assert reqs, "empty trace"
    for r in reqs:
        assert 1 <= r.prompt_len <= cfg.prompt_max
        assert 1 <= r.max_new_tokens <= cfg.output_max
        assert 0.0 < r.arrival < cfg.duration_s
    # rids are unique and ordered with arrivals
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    assert all(a.arrival <= b.arrival for a, b in zip(reqs, reqs[1:]))


def test_mean_rate_roughly_matches():
    cfg = TraceConfig(duration_s=600.0, mean_rps=5.0, rate_amplitude=0.0,
                      burstiness=1.0, seed=11)
    reqs = generate(cfg)
    rate = len(reqs) / cfg.duration_s
    assert 4.0 < rate < 6.0, rate


def test_controlled_load_phases():
    reqs = controlled_load(phases=((8, 20.0), (42, 20.0)), seed=2)
    early = [r for r in reqs if r.arrival < 20.0]
    late = [r for r in reqs if r.arrival >= 20.0]
    assert len(late) > 2 * len(early)


# ------------------------------------------------------------- presets ---
def test_scenario_registry_complete():
    for name in SCENARIOS:
        reqs = generate_scenario(name, duration_s=120.0, seed=5)
        assert reqs, name
    with pytest.raises(ValueError):
        scenario_config("no-such-scenario")


def test_scenario_determinism():
    for name in SCENARIOS:
        a = generate_scenario(name, duration_s=120.0, seed=5)
        b = generate_scenario(name, duration_s=120.0, seed=5)
        assert _sig(a) == _sig(b), name


def test_spike_peak_exceeds_steady():
    steady = generate_scenario("steady", duration_s=300.0, mean_rps=5.0,
                               seed=9)
    spike = generate_scenario("spike", duration_s=300.0, mean_rps=5.0,
                              seed=9)
    assert peak_rps(spike) > 1.5 * peak_rps(steady), \
        (peak_rps(spike), peak_rps(steady))
    # the crowd sits inside the configured window
    cfg = scenario_config("spike", 300.0, 5.0, 9)
    lo = cfg.spike_start_frac * cfg.duration_s
    hi = lo + cfg.spike_dur_frac * cfg.duration_s
    inside = [r for r in spike if lo <= r.arrival < hi]
    density_in = len(inside) / (hi - lo)
    density_out = (len(spike) - len(inside)) / (cfg.duration_s - (hi - lo))
    assert density_in > 2 * density_out


def test_diurnal_has_wider_rate_swing_than_steady():
    def swing(reqs, duration, bins=10):
        hist, _ = np.histogram([r.arrival for r in reqs],
                               bins=bins, range=(0, duration))
        return hist.max() - hist.min()

    steady = generate_scenario("steady", duration_s=600.0, seed=13)
    diurnal = generate_scenario("diurnal", duration_s=600.0, seed=13)
    assert swing(diurnal, 600.0) > 2 * swing(steady, 600.0)


def test_heavy_tail_has_fatter_length_tail():
    steady = generate_scenario("steady", duration_s=600.0, seed=17)
    heavy = generate_scenario("heavy_tail", duration_s=600.0, seed=17)

    def p99_over_median(reqs):
        lens = np.array([r.max_new_tokens for r in reqs], float)
        return np.percentile(lens, 99) / max(np.median(lens), 1.0)

    assert p99_over_median(heavy) > p99_over_median(steady)


def test_peak_rps_helper():
    from repro.serving.request import Request
    assert peak_rps([]) == 0.0
    reqs = [Request(rid=i, arrival=float(i), prompt_len=8,
                    max_new_tokens=8) for i in range(100)]
    assert peak_rps(reqs, window_s=10.0) == pytest.approx(1.1)  # 11 in 10s
