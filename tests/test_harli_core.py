"""HARLI core: two-stage predictor accuracy bands, QoS scheduler behaviour,
colocated-step equivalence, simulator end-to-end (paper headline direction)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.colocation import ColocatedRunner
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.predictor import TwoStageLatencyPredictor
from repro.core.scheduler import QoSScheduler, SchedulerConfig
from repro.core.simulator import SimConfig, simulate
from repro.models import model as MD
from repro.models.config import LoRAConfig, ModelConfig
from repro.serving.request import Request
from repro.serving.trace import TraceConfig, generate
from repro.training import peft as P
from repro.training.data import DataConfig, Prefetcher, SyntheticCorpus


@pytest.fixture(scope="module")
def fitted():
    llama = get_config("llama3-8b")
    cm = CostModel(llama, InstanceSpec(tp=2), seed=0)
    pred = TwoStageLatencyPredictor(k_max=10)
    rep = pred.fit_from_costmodel(cm)
    return cm, pred, rep


def test_predictor_error_bands(fitted):
    """Paper §8.4: solo mean err <2% / max <6%; colo mean err <5%."""
    _, _, rep = fitted
    assert rep.solo_mean_err < 0.02, rep
    assert rep.solo_max_err < 0.06, rep
    assert rep.colo_mean_err < 0.05, rep


def test_predictor_out_of_sample(fitted):
    cm, pred, _ = fitted
    errs = []
    for bs in (8, 24, 48):
        for ctx in (300, 900, 2500):
            for k in (1, 3, 6, 9):
                act = cm.colocated_round(bs, ctx, k, 2, 1024, noisy=False)
                p = pred.predict_colo(k / 10, bs, ctx)
                errs.append(abs(p - act) / act)
    assert float(np.mean(errs)) < 0.12, np.mean(errs)


def test_predictor_runtime_cost(fitted):
    _, pred, _ = fitted
    assert pred.predict_latency_us() < 100.0   # paper reports ~5us


def test_scheduler_respects_qos(fitted):
    _, pred, _ = fitted
    sched = QoSScheduler(pred, SchedulerConfig(qos_s=0.040, k_max=10))
    for bs in (1, 8, 16, 32, 64):
        d = sched.pick(bs, 1000, ft_ready=True, ft_units_available=10)
        assert d.predicted_s <= 0.040, (bs, d)
        if d.k > 0:
            worse = pred.predict_colo((d.k + 1) / 10, bs, 1000)
            assert worse > 0.040 * sched.margin or d.k == 10


def test_scheduler_preempts_when_stalled(fitted):
    _, pred, _ = fitted
    sched = QoSScheduler(pred, SchedulerConfig())
    d = sched.pick(16, 500, ft_ready=False, ft_units_available=0)
    assert d.k == 0 and d.reason == "stalled"


def test_scheduler_margin_feedback(fitted):
    _, pred, _ = fitted
    sched = QoSScheduler(pred, SchedulerConfig())
    m0 = sched.margin
    for _ in range(3):
        sched.observe(0.055)           # violations shrink the margin
    assert sched.margin < m0


def test_colocated_step_equivalence(key):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=128,
                      lora=LoRAConfig(rank=4))
    params = MD.init_params(cfg, key)
    pc = P.PeftConfig(micro_batch=2, seq_len=8, accum=1)
    pf = Prefetcher(SyntheticCorpus(DataConfig(128, 8, 2)).batches(), 2)
    ft0 = P.init_ft_state(cfg, pc, params, key, pf.stacked())
    cache0 = MD.init_cache(cfg, 3, 32)
    tok = jnp.array([1, 2, 3], jnp.int32)
    pos = jnp.array([4, 5, 6], jnp.int32)

    runner = ColocatedRunner(cfg, params, cfg, params, pc, k_max=4,
                             donate=False)
    lg_f, cache_f, ft_f = runner.run_round(3, tok, pos, cache0, ft0)

    lg_s, cache_s = jax.jit(
        lambda p, t, q, c: MD.decode_step(p, cfg, t, q, c))(
        params, tok, pos, cache0)
    us = jax.jit(P.make_unit_step(cfg, pc, params))
    ft_s = ft0
    for _ in range(3):
        ft_s = us(ft_s)

    np.testing.assert_array_equal(np.asarray(lg_f), np.asarray(lg_s))
    for a, b in zip(jax.tree.leaves(cache_f), jax.tree.leaves(cache_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ft_f), jax.tree.leaves(ft_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_simulator_paper_headline():
    """Harli must beat SeparateMode on finetune throughput with zero decode
    QoS violations (paper Fig. 11 direction)."""
    llama = get_config("llama3-8b")
    base = generate(TraceConfig(duration_s=60, mean_rps=6.0, seed=1))
    results = {}
    for mode in ("separate", "harli"):
        reqs = [Request(rid=r.rid, arrival=r.arrival,
                        prompt_len=r.prompt_len,
                        max_new_tokens=r.max_new_tokens) for r in base]
        results[mode] = simulate(llama, llama, reqs,
                                 SimConfig(mode=mode, seed=2))
    h, s = results["harli"], results["separate"]
    assert h.ft_throughput > s.ft_throughput * 1.1, \
        (h.ft_throughput, s.ft_throughput)
    assert h.qos_violation_frac < 0.01, h.qos_violation_frac
    assert h.completed == len(base)
    # decode latency sits near-but-under the QoS target (paper §8.3)
    p99 = np.percentile(h.tpot, 99)
    assert p99 <= 0.042, p99


@pytest.mark.slow
def test_simulator_window_shrinks_under_load():
    from repro.serving.trace import controlled_load
    llama = get_config("llama3-8b")
    reqs = controlled_load(phases=((8, 15.0), (42, 15.0), (24, 15.0)))
    res = simulate(llama, llama, reqs, SimConfig(mode="harli", seed=3))
    tl = res.memory_timeline
    assert tl, "no allocator timeline recorded"
    win = [s["window_bytes"] for s in tl]
    kv = [s["kv_bytes"] for s in tl]
    # §8.5: rising inference memory shrinks the finetune window
    hi_kv = max(range(len(kv)), key=kv.__getitem__)
    assert win[hi_kv] <= max(win), "window did not yield under pressure"
    assert min(win) < max(win), "window never adapted"


@pytest.mark.slow
def test_straggler_mitigation_in_simulator():
    """Injected round overruns (slow host / preempted chip) must shed
    finetune work, not decode QoS: Harli with 2% straggler rounds keeps
    violations bounded and still beats SeparateMode."""
    llama = get_config("llama3-8b")
    from repro.serving.trace import TraceConfig, generate
    base = generate(TraceConfig(duration_s=45, mean_rps=6.0, seed=9))

    def run(straggler_prob):
        reqs = [Request(rid=r.rid, arrival=r.arrival,
                        prompt_len=r.prompt_len,
                        max_new_tokens=r.max_new_tokens) for r in base]
        return simulate(llama, llama, reqs,
                        SimConfig(mode="harli", seed=10,
                                  straggler_prob=straggler_prob))

    faulty = run(0.02)
    # violations come only from the injected overruns themselves (~2%),
    # not from scheduling on top of them
    assert faulty.qos_violation_frac < 0.05, faulty.qos_violation_frac
    assert faulty.ft_throughput > 0
    assert faulty.completed == len(base)


def test_predictor_monotonicity(fitted):
    """Hypothesis-style invariant: predicted colo latency is monotone in the
    finetune quantum and in batch size."""
    _, pred, _ = fitted
    for bs in (4, 16, 48):
        lats = [pred.predict_colo(kk / 10, bs, 800) for kk in range(0, 10)]
        assert all(b >= a - 1e-5 for a, b in zip(lats, lats[1:])), (bs, lats)
    for k in (2, 6):
        l1 = pred.predict_colo(k / 10, 4, 800)
        l2 = pred.predict_colo(k / 10, 64, 800)
        assert l2 >= l1
