"""Cross-session radix prefix tree + gossiped cache summaries (PR 10).

Covers: tree semantics (cross-session hits on shared leading segments,
radix splits that preserve sibling branches, terminal-replace
truncation, node-granular LRU eviction that keeps hot shared prefixes
resident), bit-exact LRU equivalence with the PR 4 OrderedDict on
session-keyed traffic under seeded churn, digest/fingerprint agreement
between the cache side and the query side, the staleness-bound property
(a digest at or past the bound is never used), allocator conservation
under tree eviction churn in all three prefill modes, determinism of
``cache_aware_gossip`` per seed, the gossip-plane-on-but-unread path
staying bit-identical to gossip-off, and the PR's fleet-32 acceptance:
gossip routing within 10% of synchronous ``cache_aware`` TTFT p99 with
zero synchronous cache peeks at dispatch, beating session-keyed caching
on TTFT p99 at equal goodput."""

import collections

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.allocator import AllocatorConfig, UnifiedAllocator
from repro.core.api import ExperimentSpec
from repro.core.cluster import ClusterConfig, ClusterSim
from repro.core.gossip import (DIGEST_ENTRY_BYTES, DIGEST_HEADER_BYTES,
                               CacheDigest, GossipConfig, GossipPlane)
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.prefix_cache import PrefixCache, PrefixCacheConfig
from repro.core.prefix_tree import (RadixPrefixTree, normalize_segments,
                                    path_fingerprints, session_segments)
from repro.core.router import RouterConfig
from repro.core.simulator import SimConfig
from repro.serving.trace import generate_scenario

LLAMA = get_config("llama3-8b")

G, S1, S2, S3 = 1_000_000_007, 2_000_000_001, 2_000_000_002, 2_000_000_003


# ------------------------------------------------------------- tree core --
def test_tree_cross_session_hit_on_shared_segment():
    t = RadixPrefixTree(10_000)
    t.insert(((G, 384), (S1, 200)))
    # a *different* session sharing the leading segment hits it in full;
    # nothing of the match lands on its own (terminal) run
    total, final_run = t.match(((G, 384), (S2, 150)))
    assert (total, final_run) == (384, 0)
    # the owner matches both the shared segment and its own tail
    total, final_run = t.match(((G, 384), (S1, 200)))
    assert (total, final_run) == (584, 200)
    # divergence mid-segment stops the walk at the shorter length
    total, final_run = t.match(((G, 100),))
    assert (total, final_run) == (100, 100)


def test_tree_radix_split_preserves_sibling_branches():
    t = RadixPrefixTree(10_000)
    t.insert(((G, 384), (S1, 200)))
    t.insert(((G, 384), (S2, 300)))          # splits nothing: same edge
    assert t.match(((G, 384), (S1, 200))) == (584, 200)
    # a shorter shared run splits the G edge; both session tails survive
    t.insert(((G, 100), (S3, 50)))
    assert t.match(((G, 384), (S1, 200))) == (584, 200)
    assert t.match(((G, 384), (S2, 300))) == (684, 300)
    assert t.match(((G, 100), (S3, 50))) == (150, 50)
    t.check_invariants()


def test_tree_terminal_replace_truncates():
    t = RadixPrefixTree(10_000)
    t.insert(session_segments(1, 100))
    assert t.used_tokens == 100
    t.insert(session_segments(1, 60))        # shorter re-insert truncates
    assert t.used_tokens == 60
    assert t.match(session_segments(1, 100)) == (60, 60)
    t.insert(session_segments(1, 90))        # longer re-insert grows
    assert t.used_tokens == 90
    assert len(t) == 1, "an unbranched chain stays one node"
    t.check_invariants()


def test_tree_eviction_is_node_granular_and_keeps_hot_shared_prefix():
    t = RadixPrefixTree(1000)
    t.insert(((G, 400), (S1, 300)))
    t.insert(((G, 400), (S2, 300)))
    assert t.used_tokens == 1000 and len(t) == 3
    # over capacity: the LRU *leaf* (S1's tail) goes, the shared G node
    # — on every inserted path, hence most recently used — stays
    t.insert(((G, 400), (S3, 300)))
    assert t.used_tokens == 1000 and t.evicted_nodes == 1
    assert t.match(((G, 400), (S1, 1)))[0] == 400   # shared part survives
    assert t.match(((G, 400), (S1, 300))) == (400, 0)  # own tail gone
    assert t.match(((G, 400), (S3, 300))) == (700, 300)
    t.check_invariants()


def test_tree_insert_clamps_oversized_path_to_capacity():
    t = RadixPrefixTree(500)
    t.insert(((G, 400), (S1, 300)))          # 700 tokens into 500
    assert t.used_tokens == 500
    assert t.match(((G, 400), (S1, 300))) == (500, 100)
    t.check_invariants()


def test_tree_invariants_under_seeded_churn():
    rng = np.random.default_rng(5)
    t = RadixPrefixTree(2000)
    groups = [1_000_000_000 + i for i in range(3)]
    for _ in range(400):
        sid = 2_000_000_000 + int(rng.integers(12))
        path = ((int(rng.choice(groups)), int(rng.integers(50, 400))),
                (sid, int(rng.integers(1, 600))))
        op = rng.integers(3)
        if op == 0:
            t.insert(path)
        elif op == 1:
            t.match(path)
        else:
            t.touch(path)
        t.check_invariants()
    assert t.evicted_nodes > 0, "churn never hit capacity"


# ------------------------------------------- PR 4 LRU bit-equivalence --
def _alloc(total_gb=8):
    return UnifiedAllocator(AllocatorConfig(
        total_bytes=total_gb * 2 ** 30, n_layers=32,
        kv_bytes_per_token=131072, max_bs=64, qos_s=0.04,
        swap_time_s=0.002))


class _LegacyLRU:
    """The PR 4 session-keyed OrderedDict cache, re-implemented as the
    reference model: whole-entry eviction, pop-old/set-new on insert,
    move_to_end on hit, min-hit floor, last token never covered."""

    def __init__(self, capacity_tokens, min_hit_tokens):
        self.cap = capacity_tokens
        self.min_hit = min_hit_tokens
        self.d = collections.OrderedDict()
        self.evictions = 0

    def insert(self, sid, tokens):
        if self.cap <= 0 or tokens <= 0:
            return
        self.d.pop(sid, None)
        self.d[sid] = min(tokens, self.cap)
        while sum(self.d.values()) > self.cap:
            self.d.popitem(last=False)
            self.evictions += 1

    def lookup(self, sid, prompt_len):
        cached = self.d.get(sid, 0)
        hit = min(cached, prompt_len - 1)
        if hit < self.min_hit:
            return 0
        self.d.move_to_end(sid)
        return hit

    @property
    def used(self):
        return sum(self.d.values())


def test_session_keyed_tree_bit_identical_to_legacy_lru():
    """The engine swap is invisible to session-keyed traffic: a seeded
    random op stream produces identical hits, evictions and occupancy on
    the tree-backed cache and the PR 4 OrderedDict reference."""
    alloc = _alloc()
    cache = PrefixCache(PrefixCacheConfig(chunks=2, min_hit_tokens=32),
                        alloc)
    ref = _LegacyLRU(cache.capacity_tokens, 32)
    rng = np.random.default_rng(11)
    for step in range(600):
        sid = int(rng.integers(10))
        n = int(rng.integers(1, cache.capacity_tokens // 2))
        if rng.integers(2) == 0:
            cache.insert(sid, n)
            ref.insert(sid, n)
        else:
            assert cache.lookup(sid, n) == ref.lookup(sid, n), step
        assert cache.used_tokens == ref.used, step
        assert cache.stats.evictions == ref.evictions, step
        cache.check_invariants()
    assert cache.stats.hits > 0 and cache.stats.evictions > 0
    assert cache.stats.shared_hit_tokens == 0


def test_cross_session_disabled_routes_segments_to_session_path():
    """cross_session=False (the benchmark's no-sharing arm) ignores
    prefix_segments entirely — two sessions with the same shared segment
    cannot see each other's entries."""
    cache = PrefixCache(PrefixCacheConfig(chunks=2, min_hit_tokens=8,
                                          cross_session=False), _alloc())
    segs1 = ((G, 384), (S1, 116))
    segs2 = ((G, 384), (S2, 116))
    cache.insert(1, 500, segments=segs1)
    assert cache.lookup(2, 500, segments=segs2) == 0
    assert cache.lookup(1, 500, segments=segs1) == 499
    assert cache.stats.shared_hit_tokens == 0


def test_shared_hit_tokens_split_cross_session_share():
    cache = PrefixCache(PrefixCacheConfig(chunks=2, min_hit_tokens=8),
                        _alloc())
    cache.insert(1, 500, segments=((G, 384), (S1, 116)))
    # another session: the whole hit is on the non-terminal shared run
    assert cache.lookup(2, 500, segments=((G, 384), (S2, 116))) == 384
    assert cache.stats.shared_hit_tokens == 384
    # the owner: 499 total, 384 of it shared, the tail its own
    assert cache.lookup(1, 500, segments=((G, 384), (S1, 116))) == 499
    assert cache.stats.shared_hit_tokens == 384 + 384


# ------------------------------------------------- digests & staleness --
def test_digest_keys_match_query_fingerprints():
    t = RadixPrefixTree(10_000)
    t.insert(((G, 384), (S1, 200)))
    t.insert(((G, 384), (S2, 100)))
    want = dict(path_fingerprints(((G, 384), (S1, 200))))
    entries = dict(t.digest(8))
    fps = path_fingerprints(((G, 384), (S1, 200)))
    (fp_g, cum_g), (fp_s1, cum_s1) = fps
    assert entries[fp_g] == 384 and cum_g == 384
    assert entries[fp_s1] == 584 and cum_s1 == 584
    # heaviest first, deterministic
    d = t.digest(8)
    assert [c for _, c in d] == sorted((c for _, c in d), reverse=True)
    assert t.digest(1) == (d[0],)
    assert want  # fingerprints are stable across processes (FNV, not hash)


def test_digest_collapses_same_segment_continuations():
    """A radix split inside one segment must not change its digest key:
    the collapsed path fingerprint and deepest token count survive."""
    t = RadixPrefixTree(10_000)
    t.insert(((G, 384),))
    before = dict(t.digest(8))
    t.insert(((G, 100), (S3, 50)))           # splits the G edge at 100
    after = dict(t.digest(8))
    (fp_g, _), = path_fingerprints(((G, 384),))
    assert before[fp_g] == 384 and after[fp_g] == 384


def test_effective_top_k_respects_byte_budget():
    assert GossipConfig(top_k=100, max_bytes=60).effective_top_k() \
        == (60 - DIGEST_HEADER_BYTES) // DIGEST_ENTRY_BYTES
    assert GossipConfig(top_k=2, max_bytes=4096).effective_top_k() == 2
    assert GossipConfig(max_bytes=DIGEST_HEADER_BYTES).effective_top_k() \
        == 0


def test_stale_digest_is_never_used():
    """The staleness-bound property, swept over seeded probe times: a
    digest at or past the bound reads as None (a cold cache), a younger
    one is returned, and the discount decays linearly to 0 at the
    bound."""
    cfg = GossipConfig(period_s=1.0, staleness_bound_s=5.0)
    plane = GossipPlane(cfg)
    t = RadixPrefixTree(10_000)
    t.insert(((G, 384), (S1, 200)))
    d = plane.publish(3, now=10.0, tree=t)
    assert isinstance(d, CacheDigest) and d.size_bytes <= cfg.max_bytes
    rng = np.random.default_rng(3)
    for now in 10.0 + rng.uniform(0.0, 12.0, size=200):
        got = plane.get(3, float(now))
        if now - 10.0 >= cfg.staleness_bound_s:
            assert got is None
        else:
            assert got is d
            assert 0.0 < plane.discount(got.age(float(now))) <= 1.0
    assert plane.get(3, 15.0) is None            # exactly at the bound
    assert plane.discount(5.0) == 0.0
    assert plane.discount(0.0) == 1.0
    assert plane.discount(2.5) == 0.5
    assert plane.max_used_age < cfg.staleness_bound_s
    assert plane.stale_discards > 0
    plane.drop(3)
    assert plane.get(3, 10.0) is None and len(plane) == 0


# ------------------------------------------------------- cluster runs --
def _spec(policy, size=2, cross=True, gossip=None, duration=25.0,
          rps_per_inst=2.0, mode="chained", cache_chunks=16, seed=7):
    prefill = PrefillPoolConfig(n_workers=2) if mode == "pooled" else None
    return ExperimentSpec(
        name=f"gossip_{policy}_{size}", scenario="shared_prefix",
        duration_s=duration, mean_rps=rps_per_inst * size,
        n_sessions=4 * size, seed=seed,
        sim=SimConfig(mode="harli", seed=seed + 2),
        cluster=ClusterConfig(
            n_initial=size, autoscale=False, prefill_mode=mode,
            prefill=prefill,
            prefix_cache=PrefixCacheConfig(chunks=cache_chunks,
                                           cross_session=cross),
            gossip=gossip,
            router=RouterConfig(policy=policy)))


def test_cache_aware_gossip_deterministic_per_seed():
    def go():
        r = _spec("cache_aware_gossip", size=3,
                  gossip=GossipConfig()).run()
        return (r.stats, r.prefix_hits, r.prefix_hit_tokens,
                r.prefix_shared_hit_tokens, r.gossip_published,
                r.gossip_bytes, r.gossip_stale_discards,
                r.gossip_max_used_age, r.dispatch_peeks)
    assert go() == go()


def test_gossip_plane_on_but_unread_is_bit_identical_to_off():
    """Publishing digests is pure observation: with a policy that never
    reads them (cache_aware), turning the plane on must not perturb a
    single routing or simulation decision — the PR 9 behaviour is the
    gossip-off path, bit-exact."""
    off = _spec("cache_aware", size=3).run()
    on = _spec("cache_aware", size=3, gossip=GossipConfig()).run()
    assert on.stats == off.stats
    assert on.prefix_hits == off.prefix_hits
    assert on.prefix_hit_tokens == off.prefix_hit_tokens
    assert on.gossip_published > 0 and off.gossip_published == 0


@pytest.mark.parametrize("mode", ("chained", "pooled", "chunked"))
def test_allocator_conservation_under_tree_eviction_churn(mode):
    """A deliberately tiny cache (2 chunks) forces constant tree
    eviction; whatever the tree does internally, the allocator's chunk
    accounting and the tree's token accounting must both balance on
    every instance, in every prefill mode."""
    spec = _spec("cache_aware_gossip", size=2, gossip=GossipConfig(),
                 mode=mode, cache_chunks=2, duration=20.0,
                 rps_per_inst=3.0)
    reqs = spec.requests()
    cs = ClusterSim(LLAMA, LLAMA, spec.sim, spec.cluster)
    cs.run(reqs, spec.duration_s)
    churned = 0
    for inst in cs.router.all_instances():
        if inst.prefix_cache is None:
            continue
        inst.prefix_cache.check_invariants()
        inst.alloc.check_invariants()
        assert inst.alloc.prefix_chunks \
            == inst.prefix_cache.granted_chunks
        churned += inst.prefix_cache.stats.evictions
    assert churned > 0, "cache never hit capacity — no churn exercised"


def test_fleet32_gossip_acceptance():
    """The PR's acceptance pin at fleet 32 on shared_prefix:

      * cache_aware_gossip routes with ZERO synchronous cache peeks at
        dispatch (the sync policy pays O(fleet) peeks per request) and
        still lands TTFT p99 within 10% of synchronous cache_aware;
      * it beats session-keyed caching (cross_session=False — no
        sharing between sessions) on TTFT p99 at equal goodput, because
        only the tree serves the group-shared system prompt across
        sessions;
      * every digest the router used was younger than the staleness
        bound."""
    size = 32
    sync = _spec("cache_aware", size=size).run()
    gos = _spec("cache_aware_gossip", size=size,
                gossip=GossipConfig()).run()
    sk = _spec("cache_aware", size=size, cross=False).run()
    assert gos.dispatch_peeks == 0
    assert sync.dispatch_peeks > 0 and sk.dispatch_peeks > 0
    assert gos.gossip_published > 0 and gos.gossip_bytes > 0
    assert gos.gossip_max_used_age < GossipConfig().staleness_bound_s
    assert gos.prefix_shared_hit_tokens > 0
    assert sk.prefix_shared_hit_tokens == 0
    assert gos.stats.ttft_p99 <= 1.1 * sync.stats.ttft_p99, \
        (gos.stats.ttft_p99, sync.stats.ttft_p99)
    assert gos.stats.ttft_p99 < sk.stats.ttft_p99, \
        (gos.stats.ttft_p99, sk.stats.ttft_p99)
    assert gos.stats.goodput >= 0.99 * sk.stats.goodput


def test_killed_instance_digest_is_dropped():
    """A killed instance's cache is gone; its digest must leave the
    plane with it, not advertise dead KV until the bound expires."""
    spec = _spec("cache_aware_gossip", size=3, gossip=GossipConfig())
    reqs = spec.requests()
    cs = ClusterSim(LLAMA, LLAMA, spec.sim, spec.cluster)
    cs.run(reqs, 10.0)
    assert len(cs.gossip_plane) > 0
    victim = sorted(cs.router.instances)[0]
    cs._kill_instance(victim, 10.0)
    assert cs.gossip_plane.get(victim, 10.0) is None


def test_spec_v2_validation_catches_gossip_contradictions():
    from repro.core.api import SpecError

    def expect(**cl):
        spec = ExperimentSpec(name="x", scenario="shared_prefix",
                              duration_s=10, mean_rps=4, n_sessions=8,
                              cluster=ClusterConfig(**cl))
        with pytest.raises(SpecError):
            spec.validate()

    expect(gossip=GossipConfig())                   # plane without cache
    expect(prefix_cache=PrefixCacheConfig(),        # bound < period
           gossip=GossipConfig(period_s=5, staleness_bound_s=2))
    expect(prefix_cache=PrefixCacheConfig(),        # 0-entry byte budget
           gossip=GossipConfig(max_bytes=DIGEST_HEADER_BYTES))
    expect(prefix_cache=PrefixCacheConfig(),        # policy needs plane
           router=RouterConfig(policy="cache_aware_gossip"))
    expect(prefix_cache=PrefixCacheConfig(),
           gossip=GossipConfig(period_s=0))
    # and the shipped spec + a valid in-memory combination both pass
    _spec("cache_aware_gossip", gossip=GossipConfig()).validate()
    ExperimentSpec.load(
        "examples/specs/shared_prefix_gossip.json").validate()


def test_shared_prefix_scenario_tags_segments():
    reqs = generate_scenario("shared_prefix", 10.0, 8.0, seed=1,
                             n_sessions=16)
    tagged = [r for r in reqs if r.prefix_segments]
    assert tagged, "shared_prefix produced no segment-tagged requests"
    for r in tagged:
        segs = normalize_segments(r.prefix_segments)
        assert sum(n for _, n in segs) == r.prompt_len
        assert segs[0][0] < 2_000_000_000 <= segs[-1][0]
    groups = {r.prefix_segments[0][0] for r in tagged}
    assert len(groups) == 4, "scenario defaults to 4 shared-prefix groups"
