"""Cluster layer: two-tier routing plane (admission -> prefill pool ->
decode fleet), router conservation, goodput accounting, autoscaler
floor/role invariants for both control loops, and the stepped-instance
refactor's equivalence with the monolithic run loop."""

import dataclasses

import pytest

from repro.configs import get_config
from repro.core.autoscaler import (Autoscaler, AutoscalerConfig,
                                   InstanceSnapshot)
from repro.core.cluster import ClusterConfig, ClusterSim, simulate_cluster
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.prefill_pool import PrefillPoolConfig, PrefillPoolSnapshot
from repro.core.router import ClusterRouter, RouterConfig
from repro.core.simulator import DecodeInstanceSim, SimConfig
from repro.serving.request import Request
from repro.serving.trace import TraceConfig, generate, generate_scenario

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp_fallback import given, settings, strategies as st

LLAMA = get_config("llama3-8b")


def _cluster_run(mode="harli", scenario="steady", duration=25.0, rps=8.0,
                 n=2, autoscale=True, policy="least_loaded", seed=2,
                 prefill="default", sessions=0):
    reqs = generate_scenario(scenario, duration, rps, seed=seed - 1,
                             n_sessions=sessions)
    kw = {} if prefill == "default" else {"prefill": prefill}
    return simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode=mode, seed=seed),
        ClusterConfig(n_initial=n, autoscale=autoscale,
                      router=RouterConfig(policy=policy), **kw))


@pytest.fixture(scope="module")
def harli_res():
    return _cluster_run("harli")


@pytest.fixture(scope="module")
def separate_res():
    return _cluster_run("separate")


# -------------------------------------------------------------- router ---
@pytest.mark.parametrize("policy", ["least_loaded", "round_robin", "random",
                                    "predicted_latency", "session_affinity"])
def test_router_conservation(policy):
    """Every request is routed exactly once or rejected — checked by the
    router's own audit plus external accounting — under every policy and
    the prefill-pool stage."""
    res = _cluster_run(policy=policy, duration=15.0, sessions=8)
    s = res.stats
    assert s.routed + s.rejected == s.offered
    assert s.completed <= s.routed


def test_goodput_never_exceeds_throughput(harli_res, separate_res):
    for res in (harli_res, separate_res):
        s = res.stats
        assert s.goodput <= s.throughput + 1e-12
        assert 0.0 <= s.slo_attainment <= 1.0
        assert s.attained <= s.completed


def test_cluster_harli_beats_separate_ft(harli_res, separate_res):
    assert harli_res.ft_throughput > separate_res.ft_throughput


def test_cluster_determinism():
    a = _cluster_run(duration=15.0)
    b = _cluster_run(duration=15.0)
    assert a.stats == b.stats
    assert a.ft_iterations == b.ft_iterations
    assert [(d.t, d.action, d.target) for d in a.decisions] == \
        [(d.t, d.action, d.target) for d in b.decisions]


def test_router_rejects_when_saturated():
    """A tiny fleet with a harsh reject threshold must shed load — and the
    rejected requests never appear on any instance."""
    reqs = generate(TraceConfig(duration_s=10.0, mean_rps=40.0, seed=3))
    res = simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode="harli", seed=4),
        ClusterConfig(n_initial=1, autoscale=False,
                      router=RouterConfig(reject_load=0.5)))
    s = res.stats
    assert s.rejected > 0
    assert s.routed + s.rejected == s.offered


def test_removed_instances_retire_and_stop_accruing():
    """A scale-down drains the instance, then retires it: its clock stops,
    so it can't keep free-running finetune work on capacity that nominally
    left the fleet (which would inflate harli ft_throughput)."""
    duration = 40.0
    reqs = generate_scenario("steady", duration, 3.0, seed=6)
    cs = ClusterSim(LLAMA, LLAMA, SimConfig(mode="harli", seed=7),
                    ClusterConfig(n_initial=3))
    cs.run(reqs, duration)
    assert cs.router.retired, "low-load run never retired an instance"
    for inst in cs.router.retired.values():
        assert inst.drained
        assert inst.t < duration - cs.cluster.tick_s  # clock froze early


def test_saturated_instance_skipped_not_rejected():
    """Per-instance overload must not shed load while another instance is
    idle: rejection only fires under global saturation."""
    sim = SimConfig(mode="harli", seed=0)
    cm = CostModel(LLAMA, InstanceSpec(tp=sim.tp), seed=7)
    router = ClusterRouter(RouterConfig(policy="random", reject_load=0.5),
                           cm)
    hot = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    cold = DecodeInstanceSim(1, LLAMA, None, sim, None, 1)
    router.add_instance(hot)
    router.add_instance(cold)
    rid = 0
    while hot.load() <= 0.5:             # saturate instance 0 directly
        hot.enqueue(Request(rid=10_000 + rid, arrival=0.0, prompt_len=64,
                            max_new_tokens=8), 0.0)
        rid += 1
    for r in range(8):
        target = router.dispatch(Request(rid=r, arrival=0.0, prompt_len=64,
                                         max_new_tokens=8), now=0.0)
        assert target == 1, "routed to (or rejected at) the hot instance"


def test_dispatch_least_loaded_prefers_empty_instance():
    sim = SimConfig(mode="harli", seed=0)
    cm = CostModel(LLAMA, InstanceSpec(tp=sim.tp), seed=7)
    router = ClusterRouter(RouterConfig(), cm)
    a = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    b = DecodeInstanceSim(1, LLAMA, None, sim, None, 1)
    router.add_instance(a)
    router.add_instance(b)
    for rid in range(6):
        router.dispatch(Request(rid=rid, arrival=0.0, prompt_len=64,
                                max_new_tokens=8), now=0.0)
    # least_loaded alternates across the two empty instances
    assert a.queue_depth == 3 and b.queue_depth == 3
    router.check_conservation()


# ----------------------------------------------------------- autoscaler --
def _snap(i, role="colocated", load=0.5, active=1, colocatable=True,
          can_serve=True, draining=False):
    return InstanceSnapshot(inst_id=i, role=role, load=load, active=active,
                            colocatable=colocatable, can_serve=can_serve,
                            draining=draining)


def test_autoscaler_never_scales_below_min():
    a = Autoscaler(AutoscalerConfig(min_decode=1, cooldown_ticks=0))
    snaps = [_snap(0, load=0.0, active=0)]
    for t in range(50):
        d = a.evaluate(float(t), snaps, viol_frac=0.0, ft_backlog=0.0)
        assert d.action != "remove_instance"
        assert d.action != "to_finetune"


def test_autoscaler_scales_down_only_above_min():
    a = Autoscaler(AutoscalerConfig(min_decode=1, cooldown_ticks=0))
    snaps = [_snap(0, load=0.01, active=0), _snap(1, load=0.02, active=0)]
    d = a.evaluate(0.0, snaps, viol_frac=0.0, ft_backlog=0.0)
    assert d.action == "remove_instance"
    assert d.target == 0                       # least loaded goes first


def test_autoscaler_sheds_finetune_before_scaling_up():
    a = Autoscaler(AutoscalerConfig(cooldown_ticks=0))
    snaps = [_snap(0, load=0.9), _snap(1, load=0.95)]
    d = a.evaluate(0.0, snaps, viol_frac=0.10, ft_backlog=1.0)
    assert d.action == "to_decode" and d.target == 1
    snaps = [_snap(0, role="decode", load=0.9),
             _snap(1, role="decode", load=0.95)]
    d = a.evaluate(1.0, snaps, viol_frac=0.10, ft_backlog=1.0)
    assert d.action == "add_instance"


def test_autoscaler_resumes_colocation_with_headroom():
    a = Autoscaler(AutoscalerConfig(cooldown_ticks=0))
    snaps = [_snap(0, role="decode", load=0.4),
             _snap(1, role="colocated", load=0.5)]
    d = a.evaluate(0.0, snaps, viol_frac=0.0, ft_backlog=5.0)
    assert d.action == "to_colocated" and d.target == 0


def test_autoscaler_respects_max_decode():
    a = Autoscaler(AutoscalerConfig(max_decode=2, cooldown_ticks=0))
    snaps = [_snap(0, role="decode", load=2.0, colocatable=False),
             _snap(1, role="decode", load=2.0, colocatable=False)]
    d = a.evaluate(0.0, snaps, viol_frac=0.10, ft_backlog=0.0)
    assert d.action == "none"


def test_autoscaler_cooldown():
    a = Autoscaler(AutoscalerConfig(min_decode=1, cooldown_ticks=2))
    snaps = [_snap(i, load=2.0) for i in range(2)]
    first = a.evaluate(0.0, snaps, viol_frac=0.0, ft_backlog=0.0)
    assert first.action != "none"
    for t in (1.0, 2.0):
        assert a.evaluate(t, snaps, 0.0, 0.0).action == "none"
    assert a.evaluate(3.0, snaps, 0.0, 0.0).action != "none"


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(0.0, 3.0), st.floats(0.0, 0.3),
                          st.integers(0, 3)), min_size=1, max_size=30))
def test_autoscaler_floor_under_random_signals(ticks):
    """Whatever the signal sequence, the serving floor holds: with the
    fleet at min_decode the controller never removes or dedicates."""
    a = Autoscaler(AutoscalerConfig(min_decode=2, cooldown_ticks=0))
    snaps = [_snap(0), _snap(1)]
    for t, (load, viol, backlog) in enumerate(ticks):
        snaps = [_snap(0, load=load, active=int(load > 0.1)),
                 _snap(1, load=load, active=int(load > 0.1))]
        d = a.evaluate(float(t), snaps, viol, float(backlog))
        assert d.action not in ("remove_instance", "to_finetune")


def test_cluster_sim_fleet_never_below_min():
    res = _cluster_run("harli", scenario="spike", duration=30.0, rps=12.0)
    assert res.fleet_timeline, "no fleet timeline recorded"
    assert min(n for _, n, _ in res.fleet_timeline) >= 1
    assert res.final_fleet >= 1


def test_cluster_spike_triggers_scale_up():
    res = _cluster_run("harli", scenario="spike", duration=40.0, rps=12.0,
                       n=1)
    assert any(d.action == "add_instance" for d in res.decisions), \
        [d.action for d in res.decisions]
    assert res.peak_fleet > 1


def test_oversized_request_never_wedges_the_event_loop():
    """A request too large to ever fit the KV budget must be dropped at
    admission, not left at the queue head stalling step() forever."""
    sim = SimConfig(mode="harli", seed=0)
    inst = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    huge = inst.kv_budget_chunks * inst.alloc.tokens_per_chunk + 10
    inst.enqueue(Request(rid=0, arrival=0.0, prompt_len=huge,
                         max_new_tokens=8), ready_time=0.5)
    ok = Request(rid=1, arrival=0.0, prompt_len=64, max_new_tokens=4)
    inst.enqueue(ok, ready_time=1.0)
    for _ in range(10_000):
        if inst.t >= 10.0:
            break
        inst.step(10.0)
    assert inst.t >= 10.0, "event loop wedged behind oversized request"
    assert ok.finish > 0, "queued request behind the oversized one starved"
    assert inst.dropped == 1, "drop not recorded for diagnosis"


# -------------------------------------------------- two-tier plane (PR 3) --
def test_pool_beats_chain_baseline_on_spike():
    """Acceptance: on the spike scenario with fixed seeds, the
    disaggregated prefill pool + predicted_latency routing achieves TTFT
    p99 and cluster goodput at least as good as PR 1's per-instance
    prefill chain + least_loaded."""
    old = _cluster_run("harli", scenario="spike", duration=40.0, rps=10.0,
                       policy="least_loaded", prefill=None, seed=2)
    new = _cluster_run("harli", scenario="spike", duration=40.0, rps=10.0,
                       policy="predicted_latency",
                       prefill=PrefillPoolConfig(), seed=2)
    assert new.stats.ttft_p99 <= old.stats.ttft_p99, \
        (new.stats.ttft_p99, old.stats.ttft_p99)
    assert new.stats.goodput >= old.stats.goodput, \
        (new.stats.goodput, old.stats.goodput)


@pytest.mark.parametrize("policy", ["predicted_latency", "session_affinity"])
def test_new_policies_deterministic(policy):
    a = _cluster_run(policy=policy, duration=15.0, sessions=8)
    b = _cluster_run(policy=policy, duration=15.0, sessions=8)
    assert a.stats == b.stats
    assert a.prefill_timeline == b.prefill_timeline
    assert [(d.t, d.action, d.target) for d in a.decisions] == \
        [(d.t, d.action, d.target) for d in b.decisions]


def test_session_affinity_sticks_until_overflow():
    sim = SimConfig(mode="harli", seed=0)
    cm = CostModel(LLAMA, InstanceSpec(tp=sim.tp), seed=7)
    router = ClusterRouter(
        RouterConfig(policy="session_affinity",
                     affinity_overflow_load=0.1), cm)
    a = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    b = DecodeInstanceSim(1, LLAMA, None, sim, None, 1)
    router.add_instance(a)
    router.add_instance(b)
    targets = []
    for rid in range(20):
        targets.append(router.dispatch(
            Request(rid=rid, arrival=0.0, prompt_len=64, max_new_tokens=8,
                    session_id=5), now=0.0))
    # sticky while under the overflow load, then remaps to the other
    assert targets[0] == targets[1] == targets[2]
    assert len(set(targets)) == 2, "session never overflowed"
    router.check_conservation()


def test_predicted_latency_falls_back_without_predictor():
    """separate mode fits no predictor; the policy must degrade to
    least_loaded rather than crash or mis-route."""
    res = _cluster_run("separate", policy="predicted_latency",
                       duration=12.0)
    assert res.stats.completed > 0


def test_ttft_stage_accounting():
    """Pool mode must expose per-stage TTFT percentiles, and the stages
    (queue wait + prefill compute + decode-admission wait) must sum to
    TTFT exactly per request — the accounting identity, not a quantile
    relation (percentiles are not subadditive)."""
    duration = 20.0
    reqs = generate_scenario("spike", duration, 10.0, seed=1)
    cs = ClusterSim(LLAMA, LLAMA, SimConfig(mode="harli", seed=2),
                    ClusterConfig(n_initial=2))
    res = cs.run(reqs, duration)
    s = res.stats
    assert s.completed > 0
    assert s.ttft_prefill_p99 > 0
    checked = 0
    for inst in cs.router.all_instances():
        for r in inst.all_reqs:
            if r.finish < 0 or not r.token_times:
                continue
            stages = (r.prefill_start - r.arrival) \
                + (r.prefill_done - r.prefill_start) \
                + (r.token_times[0] - r.prefill_done)
            assert stages == pytest.approx(r.token_times[0] - r.arrival)
            checked += 1
    assert checked > 0


def test_two_loop_autoscaler_holds_both_floors():
    res = _cluster_run("harli", scenario="diurnal", duration=40.0, rps=3.0)
    assert res.fleet_timeline and res.prefill_timeline
    assert min(n for _, n, _ in res.fleet_timeline) >= 1
    assert min(n for _, n, _ in res.prefill_timeline) >= 1
    assert res.final_prefill >= 1


def test_pool_mode_keeps_admission_backpressure():
    """In pool mode decode load only rises after prefill, so admission
    must also read saturation off the prefill queue: a frozen fleet under
    a heavy burst rejects rather than queueing without bound."""
    reqs = generate(TraceConfig(duration_s=10.0, mean_rps=120.0, seed=3))
    res = simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode="harli", seed=4),
        ClusterConfig(n_initial=1, autoscale=False,
                      router=RouterConfig(reject_load=0.5)))
    s = res.stats
    assert s.rejected > 0
    assert s.routed + s.rejected == s.offered


def test_prefill_pool_scales_with_spike():
    res = _cluster_run("harli", scenario="spike", duration=40.0, rps=12.0)
    assert any(d.action == "add_prefill" for d in res.decisions), \
        [d.action for d in res.decisions if d.action != "none"]
    assert res.peak_prefill > 2


def test_prefill_floor_tracks_decode_fleet():
    a = Autoscaler(AutoscalerConfig(min_prefill=1, prefill_per_decode=1.0))
    assert a.prefill_floor(n_serving=3) == 3
    assert a.prefill_floor(n_serving=0) == 1          # hard floor
    a = Autoscaler(AutoscalerConfig(min_prefill=2, prefill_per_decode=0.5,
                                    max_prefill=4))
    assert a.prefill_floor(n_serving=3) == 2
    assert a.prefill_floor(n_serving=100) == 4        # capped


def test_evaluate_prefill_never_drops_below_floor():
    a = Autoscaler(AutoscalerConfig(min_prefill=2, prefill_cooldown_ticks=0))
    idle = PrefillPoolSnapshot(n_workers=2, n_draining=0, queue_depth=0,
                               backlog_s=0.0, wait_p99=0.0)
    for t in range(20):
        d = a.evaluate_prefill(float(t), idle, n_serving=1)
        assert d.action != "remove_prefill"
    shrinkable = dataclasses.replace(idle, n_workers=5)
    assert a.evaluate_prefill(99.0, shrinkable,
                              n_serving=1).action == "remove_prefill"


def test_recent_violation_frac_is_fleet_wide_by_time():
    """The QoS signal must merge samples across the fleet by time and cap
    at `window` total — a per-instance slice over-samples big fleets."""
    sim = SimConfig(mode="harli", seed=0)
    cm = CostModel(LLAMA, InstanceSpec(tp=sim.tp), seed=7)
    router = ClusterRouter(RouterConfig(tpot_slo_s=0.040), cm)
    hot = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    cold = DecodeInstanceSim(1, LLAMA, None, sim, None, 1)
    router.add_instance(hot)
    router.add_instance(cold)
    # old samples violate, recent ones don't: the fleet's last 200 by time
    # are 150 clean + 50 violating -> 0.25 (per-instance slicing gives 0.5)
    hot.quantum_timeline = [(float(t), 0, 1.0, 4) for t in range(150)]
    cold.quantum_timeline = [(150.0 + t, 0, 0.001, 4) for t in range(150)]
    assert router.recent_violation_frac(window=200) == pytest.approx(0.25)


def test_router_seed_derives_from_sim_seed():
    """`random` policy must differ across SimConfig seeds (the sentinel
    default derives router seed from SimConfig.seed), while an explicit
    seed wins — including the explicit value 0, which the old seed=0
    sentinel used to swallow."""
    def routed_seq(sim_seed, router_seed=None):
        reqs = generate_scenario("steady", 10.0, 8.0, seed=1)
        cs = ClusterSim(LLAMA, LLAMA, SimConfig(mode="harli", seed=sim_seed),
                        ClusterConfig(n_initial=3, autoscale=False,
                                      router=RouterConfig(
                                          policy="random",
                                          seed=router_seed)))
        cs.run(reqs, 10.0)
        return [rr.instance for rr in cs.router.routed], cs.router.cfg.seed
    seq_a, seed_a = routed_seq(sim_seed=2)
    seq_b, seed_b = routed_seq(sim_seed=3)
    assert seed_a != seed_b
    assert seq_a != seq_b, "random policy ignored SimConfig.seed"
    _, explicit = routed_seq(sim_seed=2, router_seed=123)
    assert explicit == 123
    # seed=0 is a real seed now (sentinel is None): same router seed under
    # different sim seeds
    _, zero_a = routed_seq(sim_seed=2, router_seed=0)
    _, zero_b = routed_seq(sim_seed=3, router_seed=0)
    assert zero_a == 0 and zero_b == 0


# ------------------------------------------------- stepped == monolithic --
def test_step_api_matches_run_wrapper():
    """Driving an instance event-by-event from outside must reproduce the
    run() wrapper exactly (same requests, same clock, same rounds)."""
    sim = SimConfig(mode="harli", seed=0)
    reqs_a = generate(TraceConfig(duration_s=10.0, mean_rps=6.0, seed=5))
    reqs_b = generate(TraceConfig(duration_s=10.0, mean_rps=6.0, seed=5))
    ready_a = {r.rid: r.arrival + 0.05 for r in reqs_a}
    ready_b = {r.rid: r.arrival + 0.05 for r in reqs_b}

    from repro.core.predictor import TwoStageLatencyPredictor
    pred = TwoStageLatencyPredictor(k_max=sim.k_max)
    pred.fit_from_costmodel(CostModel(LLAMA, InstanceSpec(tp=sim.tp),
                                      seed=13))

    a = DecodeInstanceSim(0, LLAMA, LLAMA, sim, pred, 3)
    a.run(reqs_a, ready_a, 15.0)

    b = DecodeInstanceSim(0, LLAMA, LLAMA, sim, pred, 3)
    for r in reqs_b:
        b.enqueue(r, ready_b[r.rid])
    t = 0.0
    while t < 15.0:                      # external loop in small epochs
        t = min(t + 0.25, 15.0)
        while b.t < t:
            b.step(t)
    b.collect_tpot()

    assert a.rounds == b.rounds
    assert a.result_tpot == b.result_tpot
    assert a.quantum_timeline == b.quantum_timeline
    assert [r.finish for r in reqs_a] == [r.finish for r in reqs_b]
