"""Cluster layer: router conservation, goodput accounting, autoscaler
floor/role invariants, and the stepped-instance refactor's equivalence
with the monolithic run loop."""

import pytest

from repro.configs import get_config
from repro.core.autoscaler import (Autoscaler, AutoscalerConfig,
                                   InstanceSnapshot)
from repro.core.cluster import ClusterConfig, ClusterSim, simulate_cluster
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.router import ClusterRouter, RouterConfig
from repro.core.simulator import DecodeInstanceSim, SimConfig
from repro.serving.request import Request
from repro.serving.trace import TraceConfig, generate, generate_scenario

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp_fallback import given, settings, strategies as st

LLAMA = get_config("llama3-8b")


def _cluster_run(mode="harli", scenario="steady", duration=25.0, rps=8.0,
                 n=2, autoscale=True, policy="least_loaded", seed=2):
    reqs = generate_scenario(scenario, duration, rps, seed=seed - 1)
    return simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode=mode, seed=seed),
        ClusterConfig(n_initial=n, autoscale=autoscale,
                      router=RouterConfig(policy=policy)))


@pytest.fixture(scope="module")
def harli_res():
    return _cluster_run("harli")


@pytest.fixture(scope="module")
def separate_res():
    return _cluster_run("separate")


# -------------------------------------------------------------- router ---
@pytest.mark.parametrize("policy", ["least_loaded", "round_robin", "random"])
def test_router_conservation(policy):
    """Every request is routed exactly once or rejected — checked by the
    router's own audit plus external accounting."""
    res = _cluster_run(policy=policy, duration=15.0)
    s = res.stats
    assert s.routed + s.rejected == s.offered
    assert s.completed <= s.routed


def test_goodput_never_exceeds_throughput(harli_res, separate_res):
    for res in (harli_res, separate_res):
        s = res.stats
        assert s.goodput <= s.throughput + 1e-12
        assert 0.0 <= s.slo_attainment <= 1.0
        assert s.attained <= s.completed


def test_cluster_harli_beats_separate_ft(harli_res, separate_res):
    assert harli_res.ft_throughput > separate_res.ft_throughput


def test_cluster_determinism():
    a = _cluster_run(duration=15.0)
    b = _cluster_run(duration=15.0)
    assert a.stats == b.stats
    assert a.ft_iterations == b.ft_iterations
    assert [(d.t, d.action, d.target) for d in a.decisions] == \
        [(d.t, d.action, d.target) for d in b.decisions]


def test_router_rejects_when_saturated():
    """A tiny fleet with a harsh reject threshold must shed load — and the
    rejected requests never appear on any instance."""
    reqs = generate(TraceConfig(duration_s=10.0, mean_rps=40.0, seed=3))
    res = simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode="harli", seed=4),
        ClusterConfig(n_initial=1, autoscale=False,
                      router=RouterConfig(reject_load=0.5)))
    s = res.stats
    assert s.rejected > 0
    assert s.routed + s.rejected == s.offered


def test_removed_instances_retire_and_stop_accruing():
    """A scale-down drains the instance, then retires it: its clock stops,
    so it can't keep free-running finetune work on capacity that nominally
    left the fleet (which would inflate harli ft_throughput)."""
    duration = 40.0
    reqs = generate_scenario("steady", duration, 3.0, seed=6)
    cs = ClusterSim(LLAMA, LLAMA, SimConfig(mode="harli", seed=7),
                    ClusterConfig(n_initial=3))
    cs.run(reqs, duration)
    assert cs.router.retired, "low-load run never retired an instance"
    for inst in cs.router.retired.values():
        assert inst.drained
        assert inst.t < duration - cs.cluster.tick_s  # clock froze early


def test_saturated_instance_skipped_not_rejected():
    """Per-instance overload must not shed load while another instance is
    idle: rejection only fires under global saturation."""
    sim = SimConfig(mode="harli", seed=0)
    cm = CostModel(LLAMA, InstanceSpec(tp=sim.tp), seed=7)
    router = ClusterRouter(RouterConfig(policy="random", reject_load=0.5),
                           cm)
    hot = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    cold = DecodeInstanceSim(1, LLAMA, None, sim, None, 1)
    router.add_instance(hot)
    router.add_instance(cold)
    rid = 0
    while hot.load() <= 0.5:             # saturate instance 0 directly
        hot.enqueue(Request(rid=10_000 + rid, arrival=0.0, prompt_len=64,
                            max_new_tokens=8), 0.0)
        rid += 1
    for r in range(8):
        target = router.dispatch(Request(rid=r, arrival=0.0, prompt_len=64,
                                         max_new_tokens=8), now=0.0)
        assert target == 1, "routed to (or rejected at) the hot instance"


def test_dispatch_least_loaded_prefers_empty_instance():
    sim = SimConfig(mode="harli", seed=0)
    cm = CostModel(LLAMA, InstanceSpec(tp=sim.tp), seed=7)
    router = ClusterRouter(RouterConfig(), cm)
    a = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    b = DecodeInstanceSim(1, LLAMA, None, sim, None, 1)
    router.add_instance(a)
    router.add_instance(b)
    for rid in range(6):
        router.dispatch(Request(rid=rid, arrival=0.0, prompt_len=64,
                                max_new_tokens=8), now=0.0)
    # least_loaded alternates across the two empty instances
    assert a.queue_depth == 3 and b.queue_depth == 3
    router.check_conservation()


# ----------------------------------------------------------- autoscaler --
def _snap(i, role="colocated", load=0.5, active=1, colocatable=True,
          can_serve=True, draining=False):
    return InstanceSnapshot(inst_id=i, role=role, load=load, active=active,
                            colocatable=colocatable, can_serve=can_serve,
                            draining=draining)


def test_autoscaler_never_scales_below_min():
    a = Autoscaler(AutoscalerConfig(min_decode=1, cooldown_ticks=0))
    snaps = [_snap(0, load=0.0, active=0)]
    for t in range(50):
        d = a.evaluate(float(t), snaps, viol_frac=0.0, ft_backlog=0.0)
        assert d.action != "remove_instance"
        assert d.action != "to_finetune"


def test_autoscaler_scales_down_only_above_min():
    a = Autoscaler(AutoscalerConfig(min_decode=1, cooldown_ticks=0))
    snaps = [_snap(0, load=0.01, active=0), _snap(1, load=0.02, active=0)]
    d = a.evaluate(0.0, snaps, viol_frac=0.0, ft_backlog=0.0)
    assert d.action == "remove_instance"
    assert d.target == 0                       # least loaded goes first


def test_autoscaler_sheds_finetune_before_scaling_up():
    a = Autoscaler(AutoscalerConfig(cooldown_ticks=0))
    snaps = [_snap(0, load=0.9), _snap(1, load=0.95)]
    d = a.evaluate(0.0, snaps, viol_frac=0.10, ft_backlog=1.0)
    assert d.action == "to_decode" and d.target == 1
    snaps = [_snap(0, role="decode", load=0.9),
             _snap(1, role="decode", load=0.95)]
    d = a.evaluate(1.0, snaps, viol_frac=0.10, ft_backlog=1.0)
    assert d.action == "add_instance"


def test_autoscaler_resumes_colocation_with_headroom():
    a = Autoscaler(AutoscalerConfig(cooldown_ticks=0))
    snaps = [_snap(0, role="decode", load=0.4),
             _snap(1, role="colocated", load=0.5)]
    d = a.evaluate(0.0, snaps, viol_frac=0.0, ft_backlog=5.0)
    assert d.action == "to_colocated" and d.target == 0


def test_autoscaler_respects_max_decode():
    a = Autoscaler(AutoscalerConfig(max_decode=2, cooldown_ticks=0))
    snaps = [_snap(0, role="decode", load=2.0, colocatable=False),
             _snap(1, role="decode", load=2.0, colocatable=False)]
    d = a.evaluate(0.0, snaps, viol_frac=0.10, ft_backlog=0.0)
    assert d.action == "none"


def test_autoscaler_cooldown():
    a = Autoscaler(AutoscalerConfig(min_decode=1, cooldown_ticks=2))
    snaps = [_snap(i, load=2.0) for i in range(2)]
    first = a.evaluate(0.0, snaps, viol_frac=0.0, ft_backlog=0.0)
    assert first.action != "none"
    for t in (1.0, 2.0):
        assert a.evaluate(t, snaps, 0.0, 0.0).action == "none"
    assert a.evaluate(3.0, snaps, 0.0, 0.0).action != "none"


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(0.0, 3.0), st.floats(0.0, 0.3),
                          st.integers(0, 3)), min_size=1, max_size=30))
def test_autoscaler_floor_under_random_signals(ticks):
    """Whatever the signal sequence, the serving floor holds: with the
    fleet at min_decode the controller never removes or dedicates."""
    a = Autoscaler(AutoscalerConfig(min_decode=2, cooldown_ticks=0))
    snaps = [_snap(0), _snap(1)]
    for t, (load, viol, backlog) in enumerate(ticks):
        snaps = [_snap(0, load=load, active=int(load > 0.1)),
                 _snap(1, load=load, active=int(load > 0.1))]
        d = a.evaluate(float(t), snaps, viol, float(backlog))
        assert d.action not in ("remove_instance", "to_finetune")


def test_cluster_sim_fleet_never_below_min():
    res = _cluster_run("harli", scenario="spike", duration=30.0, rps=12.0)
    assert res.fleet_timeline, "no fleet timeline recorded"
    assert min(n for _, n, _ in res.fleet_timeline) >= 1
    assert res.final_fleet >= 1


def test_cluster_spike_triggers_scale_up():
    res = _cluster_run("harli", scenario="spike", duration=40.0, rps=12.0,
                       n=1)
    assert any(d.action == "add_instance" for d in res.decisions), \
        [d.action for d in res.decisions]
    assert res.peak_fleet > 1


def test_oversized_request_never_wedges_the_event_loop():
    """A request too large to ever fit the KV budget must be dropped at
    admission, not left at the queue head stalling step() forever."""
    sim = SimConfig(mode="harli", seed=0)
    inst = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    huge = inst.kv_budget_chunks * inst.alloc.tokens_per_chunk + 10
    inst.enqueue(Request(rid=0, arrival=0.0, prompt_len=huge,
                         max_new_tokens=8), ready_time=0.5)
    ok = Request(rid=1, arrival=0.0, prompt_len=64, max_new_tokens=4)
    inst.enqueue(ok, ready_time=1.0)
    for _ in range(10_000):
        if inst.t >= 10.0:
            break
        inst.step(10.0)
    assert inst.t >= 10.0, "event loop wedged behind oversized request"
    assert ok.finish > 0, "queued request behind the oversized one starved"
    assert inst.dropped == 1, "drop not recorded for diagnosis"


# ------------------------------------------------- stepped == monolithic --
def test_step_api_matches_run_wrapper():
    """Driving an instance event-by-event from outside must reproduce the
    run() wrapper exactly (same requests, same clock, same rounds)."""
    sim = SimConfig(mode="harli", seed=0)
    reqs_a = generate(TraceConfig(duration_s=10.0, mean_rps=6.0, seed=5))
    reqs_b = generate(TraceConfig(duration_s=10.0, mean_rps=6.0, seed=5))
    ready_a = {r.rid: r.arrival + 0.05 for r in reqs_a}
    ready_b = {r.rid: r.arrival + 0.05 for r in reqs_b}

    from repro.core.predictor import TwoStageLatencyPredictor
    pred = TwoStageLatencyPredictor(k_max=sim.k_max)
    pred.fit_from_costmodel(CostModel(LLAMA, InstanceSpec(tp=sim.tp),
                                      seed=13))

    a = DecodeInstanceSim(0, LLAMA, LLAMA, sim, pred, 3)
    a.run(reqs_a, ready_a, 15.0)

    b = DecodeInstanceSim(0, LLAMA, LLAMA, sim, pred, 3)
    for r in reqs_b:
        b.enqueue(r, ready_b[r.rid])
    t = 0.0
    while t < 15.0:                      # external loop in small epochs
        t = min(t + 0.25, 15.0)
        while b.t < t:
            b.step(t)
    b.collect_tpot()

    assert a.rounds == b.rounds
    assert a.result_tpot == b.result_tpot
    assert a.quantum_timeline == b.quantum_timeline
    assert [r.finish for r in reqs_a] == [r.finish for r in reqs_b]
