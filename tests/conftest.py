import os

# Tests run single-device (the dry-run is the ONLY place that forces 512
# placeholder devices — per the assignment, never set that globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
