"""End-to-end system behaviour: real-compute co-located serving on a smoke
config — the whole Harli stack (engine + colocated runner + scheduler +
predictor) driving actual XLA programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config, get_config
from repro.core.colocation import ColocatedRunner, make_ft_only_step
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.predictor import TwoStageLatencyPredictor
from repro.core.scheduler import QoSScheduler, SchedulerConfig
from repro.models import model as MD
from repro.serving.engine import ServingEngine
from repro.serving.request import Phase, Request
from repro.training import peft as P
from repro.training.data import DataConfig, Prefetcher, SyntheticCorpus
from repro.training.optimizer import AdamWConfig


@pytest.mark.slow
def test_colocated_serving_end_to_end(key):
    cfg = smoke_config("llama3-8b")
    params = MD.init_params(cfg, key)
    eng = ServingEngine(cfg, params, max_slots=3, s_max=96)

    pc = P.PeftConfig(micro_batch=2, seq_len=16, accum=1,
                      opt=AdamWConfig(lr=1e-3))
    pf = Prefetcher(SyntheticCorpus(
        DataConfig(cfg.vocab_size, 16, 2, seed=0)).batches(), pc.n_stage)
    ft_state = P.init_ft_state(cfg, pc, params, key, pf.stacked())
    runner = ColocatedRunner(cfg, params, cfg, params, pc, k_max=4,
                             donate=False)
    pred = TwoStageLatencyPredictor(k_max=4)
    pred.fit_from_costmodel(CostModel(get_config("llama3-8b"),
                                      InstanceSpec(tp=2)))
    sched = QoSScheduler(pred, SchedulerConfig(k_max=4))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, arrival=0.0, prompt_len=int(rng.integers(6, 14)),
                    max_new_tokens=5) for i in range(5)]
    qi, rounds, units = 0, 0, 0
    while rounds < 200:
        while qi < len(reqs):
            toks = rng.integers(0, cfg.vocab_size, reqs[qi].prompt_len,
                                dtype=np.int32)
            if eng.try_admit(reqs[qi], toks):
                qi += 1
            else:
                break
        active = eng.active_requests()
        if not active and qi >= len(reqs):
            break
        bs = len(active)
        ctx = sum(r.context_len for r in active) / max(bs, 1)
        k = sched.pick(bs, ctx, ft_ready=True, ft_units_available=4).k
        tokens = jnp.asarray(eng.last_token)
        positions = np.zeros((eng.max_slots,), np.int32)
        for i, r in enumerate(eng.slots):
            if r is not None:
                positions[i] = r.context_len
        logits, eng.cache, ft_state = runner.run_round(
            k, tokens, jnp.asarray(positions), eng.cache, ft_state)
        units += k
        nt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for i, r in list(enumerate(eng.slots)):
            if r is None:
                continue
            eng.pages.extend(r.slot, 1)
            eng.last_token[i] = nt[i]
            r.generated += 1
            if r.generated >= r.max_new_tokens:
                r.phase = Phase.DONE
                eng.pages.release(r.slot)
                eng.slots[i] = None
        rounds += 1

    assert all(r.phase == Phase.DONE for r in reqs)
    assert units > 0, "no finetune units were co-scheduled"
    # finetune made real progress inside the fused programs
    assert int(ft_state["iter"]) >= 1 or int(ft_state["unit_idx"]) > 0


def test_ft_only_burst(key):
    cfg = smoke_config("qwen3-8b")
    params = MD.init_params(cfg, key)
    pc = P.PeftConfig(micro_batch=2, seq_len=12, accum=1)
    pf = Prefetcher(SyntheticCorpus(
        DataConfig(cfg.vocab_size, 12, 2, seed=1)).batches(), pc.n_stage)
    state = P.init_ft_state(cfg, pc, params, key, pf.stacked())
    burst = make_ft_only_step(cfg, params, pc, units=3)
    s2 = burst(state)
    assert int(s2["unit_idx"]) == 3
