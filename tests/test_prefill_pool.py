"""Disaggregated prefill pool: conservation, per-worker monotonicity,
EDF-vs-FIFO ordering behaviour, token-budget batching, worker lifecycle,
and determinism."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.prefill_pool import PrefillPool, PrefillPoolConfig
from repro.serving.request import Request
from repro.serving.trace import generate_scenario

LLAMA = get_config("llama3-8b")
TTFT_SLO = 4.0


def _pool(n_workers=2, ordering="edf", **kw):
    return PrefillPool(
        PrefillPoolConfig(n_workers=n_workers, ordering=ordering, **kw),
        CostModel(LLAMA, InstanceSpec(tp=2), seed=7),
        ttft_slo_s=TTFT_SLO)


def _drain(pool, reqs):
    for r in reqs:
        pool.submit(r, r.arrival)
    done = pool.pump(1e9)
    pool.check_conservation()
    return done


def _spike(duration=30.0, rps=12.0, seed=1):
    return generate_scenario("spike", duration, rps, seed=seed)


# --------------------------------------------------------- conservation ---
@pytest.mark.parametrize("ordering", ["edf", "fifo"])
def test_every_request_prefilled_exactly_once(ordering):
    reqs = _spike()
    done = _drain(_pool(ordering=ordering), reqs)
    assert len(done) == len(reqs)
    assert sorted(r.rid for r, _ in done) == sorted(r.rid for r in reqs)
    for r, t in done:
        assert r.prefill_done == t
        assert r.prefill_start >= r.arrival
        assert r.prefill_done > r.prefill_start
        assert r.prefill_worker >= 0


def test_prefill_done_monotone_per_worker():
    reqs = _spike()
    done = _drain(_pool(n_workers=3), reqs)
    by_worker = {}
    for r, _ in done:
        by_worker.setdefault(r.prefill_worker, []).append(r)
    assert len(by_worker) == 3, "a worker sat idle through a spike"
    for rs in by_worker.values():
        rs.sort(key=lambda r: r.prefill_start)
        for a, b in zip(rs, rs[1:]):
            assert b.prefill_done >= a.prefill_done
            if b.prefill_start > a.prefill_start:   # distinct batches
                assert b.prefill_start >= a.prefill_done - 1e-12
            else:                                    # same fused batch
                assert b.prefill_done == a.prefill_done


# ------------------------------------------------------------- ordering ---
def test_edf_beats_fifo_on_ttft_attainment_under_overload():
    """Deadline-aware ordering with doomed-request demotion must convert
    the same prefill capacity into strictly more TTFT-SLO-attaining
    requests than FIFO when the spike overloads the pool."""
    attain = {}
    for ordering in ("edf", "fifo"):
        done = _drain(_pool(n_workers=2, ordering=ordering),
                      _spike(rps=12.0, seed=3))
        waits = np.array([t - r.arrival for r, t in done])
        attain[ordering] = float(np.mean(waits <= TTFT_SLO))
    assert attain["edf"] > attain["fifo"] + 0.05, attain


def test_edf_ttft_p99_no_worse_when_feasible():
    """In the feasible regime (transient backlog only) the deadline-aware
    order must not regress the raw tail. FCFS provably minimizes max flow
    time, so under deep overload EDF trades raw p99 for attainment — this
    pins the feasible operating point where both hold."""
    p99 = {}
    for ordering in ("edf", "fifo"):
        done = _drain(_pool(n_workers=3, ordering=ordering),
                      _spike(rps=10.0, seed=1))
        waits = np.array([t - r.arrival for r, t in done])
        assert np.mean(waits <= TTFT_SLO) == 1.0
        p99[ordering] = float(np.percentile(waits, 99))
    assert p99["edf"] <= p99["fifo"], p99


# ------------------------------------------------------------- batching ---
def test_short_prompts_fuse_long_prompts_run_alone():
    pool = _pool(n_workers=1, max_batch=4, max_batch_tokens=512)
    shorts = [Request(rid=i, arrival=0.0, prompt_len=100, max_new_tokens=8)
              for i in range(8)]
    _drain(pool, shorts)
    w = pool.all_workers()[0]
    assert w.n_prefilled == 8
    assert w.n_batches < 8, "short prompts never fused"

    pool = _pool(n_workers=1, max_batch=4, max_batch_tokens=512)
    longs = [Request(rid=i, arrival=0.0, prompt_len=2048, max_new_tokens=8)
             for i in range(4)]
    _drain(pool, longs)
    w = pool.all_workers()[0]
    assert w.n_batches == 4, "long prompts were fused past the token budget"


def test_batched_prefill_amortizes_weight_stream():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), seed=0)
    fused = cm.prefill_batch_latency([128, 128, 128, 128])
    solo = cm.prefill_latency(128)
    assert fused < 4 * solo
    # single-prompt batch reduces exactly to the bs=1 path
    assert cm.prefill_batch_latency([512]) == pytest.approx(
        cm.prefill_latency(512))


# ------------------------------------------------------------ lifecycle ---
def test_drain_and_retire_workers():
    pool = _pool(n_workers=3)
    reqs = [Request(rid=i, arrival=0.0, prompt_len=512, max_new_tokens=8)
            for i in range(6)]
    for r in reqs:
        pool.submit(r, 0.0)
    pool.pump(0.5)
    wid = pool.drain_worker(min_workers=1)
    assert wid >= 0
    assert len(pool.active_workers()) == 2
    # drained worker takes no new batches but its history stays accounted
    before = pool.workers[wid].n_prefilled
    pool.pump(1e9)
    assert pool.workers[wid].n_prefilled == before
    pool.retire_drained(now=1e9)
    assert wid in pool.retired
    pool.check_conservation()


def test_drain_refuses_below_floor():
    pool = _pool(n_workers=2)
    assert pool.drain_worker(min_workers=2) == -1
    assert pool.drain_worker(min_workers=1) >= 0
    assert pool.drain_worker(min_workers=1) == -1


# ---------------------------------------------------------- determinism ---
def test_pool_deterministic_for_fixed_seed():
    a = _drain(_pool(), _spike(seed=5))
    b = _drain(_pool(), _spike(seed=5))
    assert [(r.rid, r.prefill_worker, t) for r, t in a] == \
        [(r.rid, r.prefill_worker, t) for r, t in b]
