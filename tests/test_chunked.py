"""Chunked-prefill deployment mode + session prefix cache (PR 4).

Covers: request conservation per policy in chunked mode, the
chunk-budget-never-exceeded invariant, the predictor's chunk pricing, the
mode-aware autoscaler loop's budget bounds, prefix-cache LRU/capacity/
allocator-charge behaviour, hit-rate determinism under a fixed seed, and
the TTFT regressions — sticky sessions beat least_loaded on a
session-heavy trace, and the cache-less PR 3 baseline is measurably worse
at equal goodput."""

import pytest

from repro.configs import get_config
from repro.core.allocator import AllocatorConfig, UnifiedAllocator
from repro.core.autoscaler import Autoscaler, AutoscalerConfig
from repro.core.cluster import ClusterConfig, ClusterSim, simulate_cluster
from repro.core.costmodel import CostModel, InstanceSpec
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.prefix_cache import PrefixCache, PrefixCacheConfig
from repro.core.router import RouterConfig
from repro.core.simulator import (ChunkedPrefillConfig, DecodeInstanceSim,
                                  SimConfig, fit_predictor)
from repro.serving.trace import generate_scenario

LLAMA = get_config("llama3-8b")


def _chunked_cfg(policy="least_loaded", cache=None, **kw):
    return ClusterConfig(n_initial=2, prefill_mode="chunked",
                         router=RouterConfig(policy=policy),
                         prefix_cache=cache, **kw)


def _run(cluster, scenario="spike", duration=20.0, rps=8.0, sessions=0,
         seed=2, mode="harli"):
    reqs = generate_scenario(scenario, duration, rps, seed=seed - 1,
                             n_sessions=sessions)
    return simulate_cluster(LLAMA, LLAMA, reqs,
                            SimConfig(mode=mode, seed=seed), cluster)


# ------------------------------------------------------------ chunked mode --
@pytest.mark.parametrize("policy", ("least_loaded", "round_robin",
                                    "random", "predicted_latency",
                                    "session_affinity"))
def test_chunked_conservation_per_policy(policy):
    """Every request routed exactly once or rejected, with the prefill
    stage living on the decode instances themselves."""
    res = _run(_chunked_cfg(policy), duration=15.0, sessions=8)
    s = res.stats
    assert s.routed + s.rejected == s.offered
    assert s.completed <= s.routed
    assert s.completed > 0


def test_chunked_has_no_prefill_tier():
    res = _run(_chunked_cfg())
    assert res.final_prefill == 0 and res.peak_prefill == 0
    assert not res.prefill_timeline
    assert not any(d.action in ("add_prefill", "remove_prefill")
                   for d in res.decisions)
    assert res.chunk_budget_timeline, "chunk budget trajectory missing"
    assert res.final_chunk_budget >= ChunkedPrefillConfig().min_budget


def test_chunk_budget_never_exceeded():
    """Invariant: no round ever carries more chunk tokens than the budget
    in force when it started (the budget may move between rounds under
    autoscaler control)."""
    duration = 30.0
    reqs = generate_scenario("spike", duration, 10.0, seed=1)
    cs = ClusterSim(LLAMA, LLAMA, SimConfig(mode="harli", seed=2),
                    _chunked_cfg())
    cs.run(reqs, duration)
    rounds = 0
    for inst in cs.router.all_instances():
        for _, tokens, budget in inst.chunk_timeline:
            assert 0 < tokens <= budget, (tokens, budget)
            rounds += 1
    assert rounds > 0, "no chunk rounds ran"


def test_chunked_mixed_rounds_meet_tpot_slo_on_spike():
    """Acceptance: mixing prefill chunks into decode rounds must keep the
    QoS guarantee — per-request TPOT p99 stays under the SLO because the
    predictor prices every chunk before admission."""
    rcfg = RouterConfig()
    res = _run(_chunked_cfg(), scenario="spike", duration=40.0, rps=10.0)
    assert res.stats.completed > 0
    assert res.stats.tpot_p99 <= rcfg.tpot_slo_s * rcfg.tpot_slack, \
        res.stats.tpot_p99


def test_chunked_deterministic():
    a = _run(_chunked_cfg("session_affinity", PrefixCacheConfig()),
             duration=15.0, sessions=8)
    b = _run(_chunked_cfg("session_affinity", PrefixCacheConfig()),
             duration=15.0, sessions=8)
    assert a.stats == b.stats
    assert a.chunk_budget_timeline == b.chunk_budget_timeline
    assert (a.prefix_hits, a.prefix_misses, a.prefix_hit_tokens) == \
        (b.prefix_hits, b.prefix_misses, b.prefix_hit_tokens)


def test_chunked_separate_mode_without_predictor():
    """separate mode fits no predictor: chunk admission must degrade to
    the deterministic cost-model price check, not crash."""
    res = _run(_chunked_cfg(), duration=15.0, mode="separate")
    assert res.stats.completed > 0


def test_mixed_round_latency_reduces_and_grows():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), noise_sigma=0)
    base = cm.mixed_round_latency(16, 512, 0, noisy=False)
    assert base == pytest.approx(
        cm.colocated_round(16, 512, 0, 2, 1024, noisy=False))
    prev = base
    for ct in (64, 128, 256, 512):
        lat = cm.mixed_round_latency(16, 512, ct, chunk_ctx=512,
                                     noisy=False)
        assert lat > prev, "chunk tokens must cost latency"
        prev = lat
    # a prefill-only round still pays the weight stream once
    assert cm.mixed_round_latency(0, 0, 256, noisy=False) > 0


def test_predictor_prices_chunks():
    """max_chunk_tokens must be the inverse of predict_mixed at the limit:
    the returned chunk is affordable, one step more is not."""
    sim = SimConfig(mode="harli", seed=0)
    pred, _ = fit_predictor(LLAMA, sim)
    assert pred.mixed_coef is not None
    assert pred.report.mixed_mean_err < 0.15
    limit = 0.040
    for bs in (4, 16, 64):
        cap = pred.max_chunk_tokens(0.0, bs, 512, limit, 4096)
        if cap <= 0:
            continue
        assert pred.predict_mixed(0.0, bs, 512, cap) <= limit * 1.001
        if cap < 4096:
            assert pred.predict_mixed(0.0, bs, 512, cap + 64) > limit


def test_autoscaler_chunk_budget_stays_in_bounds():
    a = Autoscaler(AutoscalerConfig(prefill_cooldown_ticks=0))
    lo, hi = 64, 1024
    budget = 256
    # sustained TTFT pressure grows to the cap, then escalates to fleet
    for t in range(10):
        d = a.evaluate_chunked(float(t), wait_p99=10.0, viol_frac=0.0,
                               budget=budget, lo=lo, hi=hi, n_serving=2)
        if d.action == "grow_chunk_budget":
            assert lo <= d.target <= hi
            budget = d.target
    assert budget == hi
    d = a.evaluate_chunked(99.0, wait_p99=10.0, viol_frac=0.0,
                           budget=budget, lo=lo, hi=hi, n_serving=2)
    assert d.action == "add_instance"
    # TTFT comfortable + TPOT pressure shrinks, never below the floor
    budget = 128
    for t in range(10):
        d = a.evaluate_chunked(100.0 + t, wait_p99=0.0, viol_frac=0.5,
                               budget=budget, lo=lo, hi=hi, n_serving=2)
        if d.action == "shrink_chunk_budget":
            assert lo <= d.target <= hi
            budget = d.target
    assert budget == lo


# ------------------------------------------------------------ prefix cache --
def _alloc(total_gb=8):
    return UnifiedAllocator(AllocatorConfig(
        total_bytes=total_gb * 2 ** 30, n_layers=32,
        kv_bytes_per_token=131072, max_bs=64, qos_s=0.04,
        swap_time_s=0.002))


def test_prefix_cache_charges_allocator_pool():
    alloc = _alloc()
    free0 = alloc.free_chunks
    cache = PrefixCache(PrefixCacheConfig(chunks=4), alloc)
    assert cache.granted_chunks == 4
    assert alloc.free_chunks == free0 - 4
    assert cache.capacity_tokens == 4 * alloc.tokens_per_chunk
    alloc.check_invariants()
    # an absurd ask is clamped to the reusable pool minus the reserve
    big = PrefixCache(PrefixCacheConfig(chunks=10 ** 6), alloc)
    assert big.granted_chunks <= alloc.total_chunks
    alloc.check_invariants()


def test_prefix_cache_lru_eviction_and_hits():
    alloc = _alloc()
    cache = PrefixCache(PrefixCacheConfig(chunks=1, min_hit_tokens=8),
                        alloc)
    cap = cache.capacity_tokens
    seg = cap // 2
    cache.insert(1, seg)
    cache.insert(2, seg)
    assert cache.lookup(1, seg + 1) == seg           # both resident
    cache.insert(3, seg)                             # evicts LRU == 2
    assert cache.lookup(2, seg + 1) == 0
    assert cache.lookup(1, seg + 1) == seg           # 1 was refreshed
    # hit never covers the full prompt (the new turn must prefill)
    assert cache.lookup(1, seg) == seg - 1
    # tiny hits are ignored
    assert cache.lookup(3, 4) == 0
    cache.check_invariants()
    assert cache.stats.evictions == 1


def test_prefix_cache_hit_rate_deterministic():
    """Fixed seed -> identical hit/miss/saved-token counters, run to run
    (the cache must not introduce any ordering or RNG dependence)."""
    def go():
        duration = 25.0
        reqs = generate_scenario("session_heavy", duration, 10.0, seed=1)
        cs = ClusterSim(LLAMA, LLAMA, SimConfig(mode="harli", seed=2),
                        ClusterConfig(
                            n_initial=2,
                            router=RouterConfig(policy="session_affinity"),
                            prefix_cache=PrefixCacheConfig()))
        cs.run(reqs, duration)
        stats = [(i.inst_id, i.prefix_cache.stats.hits,
                  i.prefix_cache.stats.misses,
                  i.prefix_cache.stats.hit_tokens,
                  i.prefix_cache.stats.evictions)
                 for i in cs.router.all_instances()
                 if i.prefix_cache is not None]
        return sorted(stats)
    a, b = go(), go()
    assert a == b
    assert sum(h for _, h, *_ in a) > 0, "no hits on a session-heavy trace"


def test_prefix_cache_shrinks_kv_budget():
    sim = SimConfig(mode="harli", seed=0)
    plain = DecodeInstanceSim(0, LLAMA, None, sim, None, 0)
    cached = DecodeInstanceSim(1, LLAMA, None, sim, None, 1,
                               prefix_cache=PrefixCacheConfig(chunks=8))
    assert cached.prefix_cache.granted_chunks == 8
    assert cached.kv_budget_chunks == plain.kv_budget_chunks - 8


def test_sessionless_trace_untouched_by_cache():
    """With no session ids the cache is inert: enabling it must not change
    completion accounting (capacity is reserved but never hit)."""
    on = _run(_chunked_cfg(cache=PrefixCacheConfig()), duration=15.0)
    assert on.prefix_hits == 0 and on.prefix_misses == 0
    assert on.stats.completed > 0


# ------------------------------------------------- TTFT regressions (PR 4) --
def _session_run(policy, cache, seed=2):
    duration, rps = 40.0, 12.0
    reqs = generate_scenario("session_heavy", duration, rps, seed=1,
                             n_sessions=48)
    return simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode="harli", seed=seed),
        ClusterConfig(n_initial=3, autoscale=False,
                      prefill=PrefillPoolConfig(),
                      router=RouterConfig(policy=policy),
                      prefix_cache=cache))


def test_sticky_sessions_beat_least_loaded_ttft_p99():
    """Acceptance: with the prefix cache on, session_affinity converts
    placement stability into TTFT — strictly better p99 than least_loaded
    on a session-heavy trace at equal goodput."""
    sticky = _session_run("session_affinity", PrefixCacheConfig(chunks=8))
    spread = _session_run("least_loaded", PrefixCacheConfig(chunks=8))
    assert sticky.prefix_hits > 0
    assert sticky.stats.ttft_p99 < spread.stats.ttft_p99, \
        (sticky.stats.ttft_p99, spread.stats.ttft_p99)
    assert sticky.stats.goodput >= spread.stats.goodput


def test_prefix_cache_beats_cacheless_baseline_ttft_p99():
    """Acceptance: session_affinity + cache improves TTFT p99 measurably
    over the cache-less PR 3 baseline at equal goodput."""
    cached = _session_run("session_affinity", PrefixCacheConfig(chunks=8))
    bare = _session_run("session_affinity", None)
    assert cached.stats.ttft_p99 < 0.9 * bare.stats.ttft_p99, \
        (cached.stats.ttft_p99, bare.stats.ttft_p99)
    assert cached.stats.goodput >= bare.stats.goodput


def test_pooled_affinity_pins_sticky_instance():
    """In pooled mode the sticky instance is chosen at admission (so the
    cache can shorten prefill) and honored at hand-off: a session's
    completed requests land on one instance while it has headroom."""
    duration = 20.0
    reqs = generate_scenario("session_heavy", duration, 6.0, seed=1,
                             n_sessions=6)
    cs = ClusterSim(LLAMA, LLAMA, SimConfig(mode="harli", seed=2),
                    ClusterConfig(n_initial=2, autoscale=False,
                                  prefill=PrefillPoolConfig(),
                                  router=RouterConfig(
                                      policy="session_affinity"),
                                  prefix_cache=PrefixCacheConfig()))
    cs.run(reqs, duration)
    placed = {}
    for inst in cs.router.all_instances():
        for r in inst.all_reqs:
            placed.setdefault(r.session_id, set()).add(inst.inst_id)
    multi = [s for s, insts in placed.items() if len(insts) > 1]
    # light load: sessions stay pinned (overflow would need load > 1.0)
    assert not multi, f"sessions split across instances: {multi}"
