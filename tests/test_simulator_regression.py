"""Seeded end-to-end simulator regression: the paper's headline direction
(harli > separate on finetune throughput at held decode QoS) plus strict
determinism — the same seed must reproduce the identical SimResult."""

import dataclasses

import pytest

from repro.configs import get_config
from repro.core.simulator import SimConfig, simulate
from repro.serving.request import Request
from repro.serving.trace import TraceConfig, generate

QOS_S = 0.040
# paper §8.2 reports ≥99% TPOT attainment for Harli; assert with margin
QOS_ATTAIN_TARGET = 0.97


def _trace(seed=1, duration=30.0, rps=4.0):
    return generate(TraceConfig(duration_s=duration, mean_rps=rps,
                                seed=seed))


def _run(mode, seed=2, trace_seed=1):
    llama = get_config("llama3-8b")
    reqs = _trace(seed=trace_seed)
    return simulate(llama, llama, reqs,
                    SimConfig(mode=mode, qos_s=QOS_S, seed=seed))


@pytest.fixture(scope="module")
def results():
    return {m: _run(m) for m in ("harli", "separate")}


def test_harli_beats_separate_ft_throughput(results):
    h, s = results["harli"], results["separate"]
    assert h.ft_throughput > s.ft_throughput, \
        (h.ft_throughput, s.ft_throughput)


def test_harli_keeps_decode_qos(results):
    h = results["harli"]
    assert h.tpot, "no decode TPOT samples collected"
    attained = 1.0 - h.qos_violation_frac
    assert attained >= QOS_ATTAIN_TARGET, attained


def test_all_requests_complete(results):
    n = len(_trace())
    for mode, res in results.items():
        assert res.completed == n, (mode, res.completed, n)


def test_finetune_makes_progress_in_all_modes(results):
    for mode, res in results.items():
        assert res.ft_iterations > 0, mode
        assert res.ft_units_done > 0, mode


def _comparable(res):
    """SimResult minus the predictor report (an object without __eq__)."""
    d = dataclasses.asdict(res)
    d.pop("predictor_report")
    return d


def test_determinism_same_seed_identical_result():
    a = _run("harli", seed=4, trace_seed=3)
    b = _run("harli", seed=4, trace_seed=3)
    assert _comparable(a) == _comparable(b)


def test_different_seed_differs():
    """Sanity check that the determinism test has teeth: noise seeds do
    change the fine-grained result."""
    a = _run("harli", seed=4, trace_seed=3)
    b = _run("harli", seed=5, trace_seed=3)
    assert _comparable(a) != _comparable(b)
