"""Sharded-execution correctness + dry-run machinery.

The numerical test runs in a subprocess with 8 forced host devices (the
assignment forbids setting the device-count flag globally): a smoke model's
train step jitted with the production sharding rules on a 2x4 mesh must
match the single-device result.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs import SHAPES, cells

SRC = str(Path(__file__).parents[1] / "src")

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs import smoke_config
from repro.distributed import partitioning as PT
from repro.distributed.sharding import use_mesh
from repro.models import model as MD
from repro.training import peft as P
from repro.training.optimizer import AdamWConfig, adamw_init

cfg = smoke_config("%ARCH%")
key = jax.random.PRNGKey(0)
params = MD.init_params(cfg, key)
adapters = MD.init_adapters(cfg, key)
opt = adamw_init(adapters)
B, S = 8, 16
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
if cfg.enc_layers:
    batch["enc_frames"] = jax.random.normal(key, (B, 8, cfg.d_model))

step = P.make_train_step(cfg, AdamWConfig(lr=1e-3), remat=True)
# single-device reference
ad_ref, _, m_ref = jax.jit(step)(params, adapters, opt, batch)

mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
shardings = (PT.param_specs(cfg, params, mesh),
             PT.adapter_specs(cfg, adapters, mesh),
             jax.tree.map(lambda _: jax.sharding.PartitionSpec(), opt),
             PT.batch_specs(batch, mesh))
named = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                     shardings,
                     is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
with use_mesh(mesh):
    ad_sh, _, m_sh = jax.jit(step, in_shardings=named)(
        params, adapters, opt, batch)

print("loss_ref", float(m_ref["loss"]), "loss_sharded", float(m_sh["loss"]))
assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 5e-2
for a, b in zip(jax.tree.leaves(ad_ref), jax.tree.leaves(ad_sh)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=5e-3, rtol=5e-2)
print("SHARDED_OK %ARCH%")
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x7b", "mamba2-780m"])
def test_sharded_train_step_matches_single_device(arch, tmp_path):
    script = tmp_path / "sharded.py"
    script.write_text(SHARDED_SCRIPT.replace("%ARCH%", arch))
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, timeout=900,
                       env={**__import__("os").environ, "PYTHONPATH": SRC})
    assert f"SHARDED_OK {arch}" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_cell_grid_complete():
    """The assigned grid is 10 archs x 4 shapes = 40 cells; skips only for
    long_500k on pure full-attention archs."""
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40
    skipped = [(a, s) for a, s, skip in all_cells if skip]
    assert all(s == "long_500k" for _, s in skipped)
    assert len(skipped) == 6
    assert len(SHAPES) == 4


def test_dryrun_results_if_present():
    """When the dry-run has produced results, every recorded cell must have
    compiled OK and fit per-chip HBM."""
    results = Path(__file__).parents[1] / "dryrun_results"
    files = list(results.glob("*.json")) if results.exists() else []
    if not files:
        pytest.skip("dry-run results not generated in this environment")
    hbm = 16 * 1024 ** 3
    for f in files:
        rec = json.loads(f.read_text())
        assert rec.get("ok"), f"{f.name}: {rec.get('error')}"
        m = rec["memory"]
        # TPU fit gate: the CPU-measured resident minus identified f32
        # legalization artifacts, cross-checked by the analytic activation
        # watermark (EXPERIMENTS.md §Dry-run documents the three figures)
        candidates = [v for v in (m.get("resident_tpu_bytes"),
                                  m.get("resident_analytic_bytes"))
                      if v is not None]
        resident = min(candidates) if candidates else (
            m["argument_size_in_bytes"] + m["temp_size_in_bytes"]
            + m["output_size_in_bytes"] - m["alias_size_in_bytes"])
        assert resident < hbm, \
            f"{f.name}: {resident/2**30:.1f} GiB exceeds v5e HBM"


def test_hlo_analysis_trip_counts():
    """The HLO analyzer must multiply dot flops by scan trip counts."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze

    def f(x, ws):
        def body(h, w):
            return jnp.dot(h, w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    x = jnp.zeros((8, 16))
    ws = jnp.zeros((5, 16, 16))
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    stats = analyze(hlo)
    expect = 2 * 8 * 16 * 16 * 5
    assert stats.dot_flops == expect, (stats.dot_flops, expect)
    assert 5 in stats.loop_trip_counts
