"""Control-plane API (PR 5): policy registry, ExperimentSpec, the
cache_aware routing plugin and the fused finetune quantum.

Covers: registry registration / unknown-name error text / duplicate
rejection / end-to-end pluggability of a test-local policy;
ExperimentSpec JSON round-trip determinism (same JSON -> seed-identical
run); the contradictory-flag validation (satellite bugfix); a regression
pinning the legacy string-kwarg construction bit-identical to the
spec-driven path for one scenario per prefill mode; heterogeneous
per-instance overrides; cache_aware beating session_affinity on TTFT p99
in the session_heavy scenario at equal goodput; and the fused-quantum
flag raising finetune throughput inside the TPOT SLO (default off).

The PR 9 deprecation shims (ClusterRouter prefill_pool=/mode= kwargs,
router.POLICIES/PREFILL_MODES tuples) and their capture tests were
removed in PR 10 at the scheduled re-anchor; the string-kwarg
simulate_cluster path above is NOT deprecated and stays pinned."""

import dataclasses
import glob
import os

import pytest

from repro.configs import get_config
from repro.core import api
from repro.core.api import (ExperimentSpec, PolicyNotFoundError, SpecError,
                            RoutingPolicy, available_policies,
                            register_policy, resolve_policy)
from repro.core.cluster import ClusterConfig, ClusterSim, simulate_cluster
from repro.core.prefill_pool import PrefillPoolConfig
from repro.core.prefix_cache import PrefixCacheConfig
from repro.core.router import RouterConfig
from repro.core.simulator import ChunkedPrefillConfig, SimConfig
from repro.serving.trace import generate_scenario

LLAMA = get_config("llama3-8b")
SPEC_DIR = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "specs")


# ------------------------------------------------------------- registry --
def test_registry_lists_builtins():
    assert set(available_policies("routing")) >= {
        "least_loaded", "round_robin", "random", "predicted_latency",
        "session_affinity", "cache_aware"}
    assert set(available_policies("prefill")) == {
        "chained", "pooled", "chunked"}
    assert set(available_policies("scaling")) == {
        "decode_fleet", "pooled_prefill", "chunked_budget"}
    assert set(available_policies("adapter_placement")) == {
        "affinity_packed", "replicate_hot"}


def test_registry_unknown_name_error_text():
    """The error must name the kind, the bad name, and what IS registered
    — a typo'd spec run fails with the fix in the message."""
    with pytest.raises(PolicyNotFoundError) as ei:
        resolve_policy("routing", "least_loadedd")
    msg = str(ei.value)
    assert "unknown routing policy 'least_loadedd'" in msg
    assert "least_loaded" in msg and "cache_aware" in msg
    with pytest.raises(PolicyNotFoundError):
        resolve_policy("prefill", "pool")


def test_registry_suggestions_scoped_to_requested_kind():
    """The suggestion list names only the requested kind's policies —
    an adapter_placement typo must not suggest routing or scaling names
    (and vice versa), or the 'fix in the message' points at a name that
    cannot resolve for that kind."""
    with pytest.raises(PolicyNotFoundError) as ei:
        resolve_policy("adapter_placement", "affinity_packd")
    msg = str(ei.value)
    assert "unknown adapter_placement policy" in msg
    assert "affinity_packed" in msg and "replicate_hot" in msg
    for other_kind_name in ("least_loaded", "chained", "decode_fleet",
                            "kv_headroom"):
        assert other_kind_name not in msg
    with pytest.raises(PolicyNotFoundError) as ei:
        resolve_policy("scaling", "affinity_packed")
    msg = str(ei.value)
    assert "decode_fleet" in msg and "replicate_hot" not in msg
    with pytest.raises(ValueError, match="unknown policy kind"):
        resolve_policy("adapters", "affinity_packed")


def test_registry_rejects_duplicate_name():
    with pytest.raises(ValueError, match="already registered"):
        @register_policy("least_loaded")
        class Impostor(RoutingPolicy):       # noqa: F811
            def pick(self, cand, req, router):
                return cand[0]


def test_registry_infers_kind_or_rejects():
    with pytest.raises(TypeError, match="subclasses none"):
        @register_policy("not_a_policy")
        class Plain:
            pass


def test_custom_policy_plugs_in_end_to_end():
    """A policy registered through the public decorator is reachable by
    name from RouterConfig with zero router edits — the API contract
    cache_aware relies on."""
    name = "test_always_highest_id"
    if name not in available_policies("routing"):
        @register_policy(name)
        class HighestId(RoutingPolicy):
            def pick(self, cand, req, router):
                return max(cand, key=lambda i: i.inst_id)

    reqs = generate_scenario("steady", 10.0, 6.0, seed=1)
    res = simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode="harli", seed=2),
        ClusterConfig(n_initial=2, autoscale=False, prefill_mode="chained",
                      prefill=None, router=RouterConfig(policy=name)))
    assert res.stats.completed > 0
    assert res.stats.routed + res.stats.rejected == res.stats.offered


def test_unknown_policy_fails_at_construction():
    with pytest.raises(PolicyNotFoundError):
        simulate_cluster(
            LLAMA, LLAMA, [], SimConfig(mode="harli", seed=2),
            ClusterConfig(router=RouterConfig(policy="no_such_policy")))


# ------------------------------------------------------- ExperimentSpec --
def _spec(mode="pooled", policy="least_loaded", duration=12.0, rps=8.0,
          scenario="spike", sessions=0, cache=None, **cluster_kw):
    kw = dict(prefill_mode=mode, prefill=None)
    if mode == "pooled":
        kw["prefill"] = PrefillPoolConfig()
    kw.update(cluster_kw)
    return ExperimentSpec(
        name=f"test_{mode}_{policy}", scenario=scenario,
        duration_s=duration, mean_rps=rps, n_sessions=sessions, seed=1,
        sim=SimConfig(mode="harli", seed=2),
        cluster=ClusterConfig(n_initial=2, router=RouterConfig(policy=policy),
                              prefix_cache=cache, **kw))


@pytest.mark.parametrize("mode", ["chained", "pooled", "chunked"])
def test_spec_json_round_trip_equality(mode):
    s = _spec(mode, sessions=6, cache=PrefixCacheConfig())
    s2 = ExperimentSpec.from_json(s.to_json())
    assert s2 == s
    # and again through a dict (tuples restored, nested optionals intact)
    assert ExperimentSpec.from_dict(s2.to_dict()) == s


def test_spec_json_round_trip_run_is_seed_identical():
    """from_json(to_json(s)).run() must be bit-identical to s.run() — the
    spec file IS the experiment."""
    s = _spec("pooled", "session_affinity", sessions=8,
              cache=PrefixCacheConfig())
    a = s.run()
    b = ExperimentSpec.from_json(s.to_json()).run()
    assert a.stats == b.stats
    assert a.ft_iterations == b.ft_iterations
    assert (a.prefix_hits, a.prefix_misses) == (b.prefix_hits,
                                                b.prefix_misses)


def test_spec_rejects_unknown_fields_with_valid_names():
    with pytest.raises(SpecError, match="unknown ExperimentSpec field"):
        ExperimentSpec.from_json('{"nam": "typo"}')
    with pytest.raises(SpecError, match="unknown SimConfig field"):
        ExperimentSpec.from_json('{"sim": {"qos": 0.04}}')


def test_spec_validation_catches_contradictions():
    """The satellite bugfix: contradictory knob combinations error loudly
    instead of being silently ignored (centralized in validate())."""
    # pooled mode without a pool config
    with pytest.raises(SpecError, match="needs a prefill pool config"):
        _spec("pooled", prefill=None).validate()
    # a configured pool outside pooled mode (--prefill-workers + chained)
    with pytest.raises(SpecError, match="only exists in pooled mode"):
        _spec("chained",
              prefill=PrefillPoolConfig(n_workers=4)).validate()
    # chunked knobs outside chunked mode (--chunk-budget + pooled)
    with pytest.raises(SpecError, match="only apply in chunked mode"):
        _spec("pooled",
              chunked=ChunkedPrefillConfig(budget_tokens=512)).validate()
    # unknown names surface the registry error
    with pytest.raises(SpecError, match="unknown routing policy"):
        _spec("pooled", policy="least_loadedd").validate()
    with pytest.raises(SpecError, match="unknown scenario"):
        _spec("pooled", scenario="spikey").validate()
    # non-overridable per-instance fields
    with pytest.raises(SpecError, match="non-overridable"):
        _spec("chained",
              instance_overrides=({"seed": 3},)).validate()
    # a full trace override must be mirrored by the top-level trace-shape
    # fields (they feed reports/duration scaling; disagreement would be a
    # silently ignored knob)
    from repro.serving.trace import TraceConfig
    with pytest.raises(SpecError, match="disagrees with trace.duration_s"):
        dataclasses.replace(
            _spec("chained"), duration_s=99.0,
            trace=TraceConfig(duration_s=12.0, mean_rps=8.0)).validate()
    # the defaults themselves are fine in every mode
    for mode in ("chained", "pooled", "chunked"):
        _spec(mode).validate()


def test_cli_rejects_contradictory_and_overridden_flags():
    """The CLI must reject mode-gated flags even when their value equals
    the config default (--prefill-workers 2 with chained mode), and any
    experiment flag next to --spec — both were silently ignored before
    PR 5."""
    import subprocess
    import sys
    example = os.path.join(os.path.dirname(__file__), "..", "examples",
                           "cluster_sim.py")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))

    def run(*flags):
        return subprocess.run([sys.executable, example, *flags],
                              capture_output=True, text=True, env=env)

    r = run("--prefill-workers", "2", "--prefill-mode", "chained")
    assert r.returncode != 0
    assert "--prefill-workers only applies" in r.stderr
    r = run("--chunk-budget", "256")          # default value, pooled mode
    assert r.returncode != 0
    assert "--chunk-budget only applies" in r.stderr
    r = run("--fuse-quantum", "--prefill-mode", "pooled")
    assert r.returncode != 0
    assert "--fuse-quantum only applies" in r.stderr
    r = run("--spec", os.path.join(SPEC_DIR, "spike_pooled.json"),
            "--policy", "session_affinity")
    assert r.returncode != 0
    assert "runs the file as-is" in r.stderr and "--policy" in r.stderr


def test_committed_spec_files_validate():
    paths = sorted(glob.glob(os.path.join(SPEC_DIR, "*.json")))
    assert len(paths) >= 4, "canonical examples/specs/*.json set missing"
    for p in paths:
        ExperimentSpec.load(p).validate()


# ----------------------------------------------- back-compat regression --
@pytest.mark.parametrize("mode,policy", [
    ("chained", "least_loaded"),
    ("pooled", "session_affinity"),
    ("chunked", "predicted_latency"),
])
def test_legacy_kwargs_bit_identical_to_spec(mode, policy):
    """The deprecation shims: constructing the experiment the pre-registry
    way (string kwargs into simulate_cluster) is bit-identical to the
    spec-driven path, one scenario per prefill mode."""
    spec = _spec(mode, policy, duration=15.0, sessions=8,
                 cache=PrefixCacheConfig())
    via_spec = spec.run()
    reqs = generate_scenario(spec.scenario, spec.duration_s, spec.mean_rps,
                             seed=spec.seed + 1,
                             n_sessions=spec.n_sessions)
    via_kwargs = simulate_cluster(
        LLAMA, LLAMA, reqs, SimConfig(mode="harli", seed=2),
        ClusterConfig(n_initial=2, prefill_mode=mode,
                      prefill=PrefillPoolConfig() if mode == "pooled"
                      else None,
                      router=RouterConfig(policy=policy),
                      prefix_cache=PrefixCacheConfig()))
    assert via_spec.stats == via_kwargs.stats
    assert via_spec.ft_iterations == via_kwargs.ft_iterations
    assert via_spec.chunk_budget_timeline == via_kwargs.chunk_budget_timeline
    assert [(d.t, d.action, d.target) for d in via_spec.decisions] == \
        [(d.t, d.action, d.target) for d in via_kwargs.decisions]


# --------------------------------------------- heterogeneous overrides --
def test_instance_overrides_build_heterogeneous_fleet():
    spec = _spec("chained", duration=8.0, rps=5.0, scenario="steady",
                 instance_overrides=({"tp": 4, "max_slots": 32}, {}))
    spec.validate()
    cs = ClusterSim(LLAMA, LLAMA, spec.sim, spec.cluster)
    by_id = {i.inst_id: i for i in cs.router.instances.values()}
    assert by_id[0].sim.tp == 4 and by_id[0].sim.max_slots == 32
    assert by_id[1].sim.tp == spec.sim.tp
    res = cs.run(spec.requests(), spec.duration_s)
    assert res.stats.completed > 0
    assert res.stats.routed + res.stats.rejected == res.stats.offered


# ------------------------------------------------- cache_aware routing --
def _cache_spec(policy, seed=1):
    return ExperimentSpec(
        name=f"cache_{policy}", scenario="session_heavy", duration_s=40.0,
        mean_rps=14.0, n_sessions=24, seed=seed,
        sim=SimConfig(mode="harli", seed=seed + 1, max_slots=32),
        cluster=ClusterConfig(
            n_initial=3, autoscale=False, prefill_mode="pooled",
            prefill=PrefillPoolConfig(),
            router=RouterConfig(policy=policy),
            prefix_cache=PrefixCacheConfig(chunks=16)))


def test_cache_aware_beats_session_affinity_ttft_p99():
    """Acceptance: on the session_heavy scenario, cache_aware routing —
    registered purely through the public API — beats session_affinity on
    TTFT p99 at equal goodput. The sticky map is load-blind; the plugin
    reads every instance's PrefixCache and trades cached-prefix savings
    against queue depth continuously."""
    aware = _cache_spec("cache_aware").run()
    sticky = _cache_spec("session_affinity").run()
    assert aware.prefix_hits > 0
    assert aware.stats.ttft_p99 < sticky.stats.ttft_p99, \
        (aware.stats.ttft_p99, sticky.stats.ttft_p99)
    assert aware.stats.goodput >= sticky.stats.goodput
    # and it keeps (or beats) the sticky policy's cache efficiency
    assert aware.prefix_hits >= 0.9 * sticky.prefix_hits


def test_cache_aware_deterministic_and_conserving():
    a = _cache_spec("cache_aware").run()
    b = _cache_spec("cache_aware").run()
    assert a.stats == b.stats
    assert (a.prefix_hits, a.prefix_misses, a.prefix_hit_tokens) == \
        (b.prefix_hits, b.prefix_misses, b.prefix_hit_tokens)
    assert a.stats.routed + a.stats.rejected == a.stats.offered


def test_cache_aware_sessionless_falls_back_to_least_loaded():
    """Without session ids the plugin must degrade gracefully (no cache
    to read) and still conserve requests in every mode."""
    for mode in ("chained", "pooled", "chunked"):
        res = _spec(mode, "cache_aware", duration=10.0).run()
        s = res.stats
        assert s.completed > 0
        assert s.routed + s.rejected == s.offered


def test_prefix_cache_peek_matches_lookup_without_mutation():
    from repro.core.allocator import AllocatorConfig, UnifiedAllocator
    from repro.core.prefix_cache import PrefixCache
    alloc = UnifiedAllocator(AllocatorConfig(
        total_bytes=8 * 2 ** 30, n_layers=32, kv_bytes_per_token=131072,
        max_bs=64, qos_s=0.04, swap_time_s=0.002))
    cache = PrefixCache(PrefixCacheConfig(chunks=2, min_hit_tokens=8),
                        alloc)
    cache.insert(1, 500)
    before = dataclasses.replace(cache.stats)
    assert cache.peek(1, 400) == 399        # min(cached, prompt-1)
    assert cache.peek(1, 1000) == 500
    assert cache.peek(2, 400) == 0          # miss
    assert cache.peek(1, 4) == 0            # under min_hit_tokens
    assert cache.stats == before, "peek mutated stats"
    assert cache.peek(1, 400) == cache.lookup(1, 400)


# ---------------------------------------------- fused finetune quantum --
def _fused_spec(fuse):
    from repro.serving.trace import TraceConfig
    return ExperimentSpec(
        name="fused", duration_s=40.0, mean_rps=5.0, seed=0,
        trace=TraceConfig(duration_s=40.0, mean_rps=5.0, burstiness=1.0,
                          rate_amplitude=0.05, prompt_median=1024,
                          output_median=128, seed=1),
        sim=SimConfig(mode="harli", seed=2),
        cluster=ClusterConfig(
            n_initial=2, autoscale=False, prefill_mode="chunked",
            prefill=None,
            chunked=ChunkedPrefillConfig(fuse_quantum=fuse,
                                         budget_tokens=512),
            router=RouterConfig()))


def test_fused_quantum_raises_ft_throughput_within_slo():
    """Satellite: with fuse_quantum on, chunk-carrying rounds run a
    reduced finetune quantum when the fused predictor stage prices both
    as fitting — finetune throughput rises on a prefill-heavy trace
    while TPOT p99 stays inside the SLO and goodput is untouched (the
    backlog guard keeps fused rounds off the TTFT critical path).
    Default off."""
    assert ChunkedPrefillConfig().fuse_quantum is False
    off = _fused_spec(False).run()
    on = _fused_spec(True).run()
    rcfg = RouterConfig()
    lim = rcfg.tpot_slo_s * rcfg.tpot_slack
    assert on.ft_throughput > off.ft_throughput, \
        (on.ft_throughput, off.ft_throughput)
    assert on.stats.tpot_p99 <= lim, on.stats.tpot_p99
    assert on.stats.goodput >= 0.99 * off.stats.goodput


def test_fused_quantum_rounds_record_nonzero_k():
    """The fused rounds are visible in the quantum timeline: chunk rounds
    (which force k=0 without the flag) carry k>0 with it."""
    spec = _fused_spec(True)
    cs = ClusterSim(LLAMA, LLAMA, spec.sim, spec.cluster)
    cs.run(spec.requests(), spec.duration_s)
    fused = 0
    for inst in cs.router.all_instances():
        chunk_starts = {round(t, 9) for t, _, _ in inst.chunk_timeline}
        for t_end, k, lat, bs in inst.quantum_timeline:
            if k > 0 and bs > 0 and round(t_end - lat, 9) in chunk_starts:
                fused += 1
    assert fused > 0, "no chunk-carrying round ever fused a quantum"
