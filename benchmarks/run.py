# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: paper-figure reproductions + roofline extraction.

    PYTHONPATH=src python -m benchmarks.run [--only fig11] [--quick]

Figures run against the TPU v5e cost model / discrete-event simulator
(DESIGN.md §2: the container's stand-in for hardware profiling); the
roofline section reads the dry-run artifacts in dryrun_results/.
"""

from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="substring filter on figure function names")
    ap.add_argument("--quick", action="store_true",
                    help="shorter traces for the simulator figures")
    args = ap.parse_args()

    from benchmarks import paper_figures as F
    from benchmarks import roofline as R

    print("name,us_per_call,derived")
    t_all = time.time()
    failed = []
    quick_durations = {"fig11_throughput_qos": 45.0,
                       "sec87_tp_mode": 45.0,
                       "cluster_goodput": 40.0,
                       "cluster_fleet_timeline": 40.0,
                       "cluster_prefill_modes": 40.0,
                       "cluster_cache_aware": 40.0,
                       "cluster_churn": 40.0,
                       "cluster_survivability": 40.0,
                       "cluster_adapter_serving": 40.0,
                       "cluster_prefix_gossip": 40.0}
    for fn in F.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            if args.quick and fn.__name__ in quick_durations:
                fn(duration_s=quick_durations[fn.__name__])
            else:
                fn()
            print(f"# {fn.__name__}: {time.time()-t0:.1f}s")
        except Exception as e:
            print(f"# {fn.__name__} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
            failed.append(fn.__name__)

    if not args.only or "roofline" in args.only:
        try:
            rows = R.load_all("single")
            if rows:
                print()
                print("# Roofline (single-pod; see EXPERIMENTS.md §Roofline)")
                print(R.fmt_table(rows))
                for r in rows:
                    bound = max(r["compute_s"], r["memory_s"],
                                r["collective_s"])
                    print(f"roofline,{r['arch']}__{r['shape']},"
                          f"{bound*1e6:.1f},"
                          f"{r['dominant']}|frac={r['roofline_frac']:.3f}"
                          f"|useful={r['useful_ratio']:.3f}")
            else:
                print("# roofline: no dryrun_results found (run "
                      "repro.launch.dryrun first)")
        except Exception as e:
            print(f"# roofline FAILED: {type(e).__name__}: {e}")
            failed.append("roofline")
    print(f"# total: {time.time()-t_all:.1f}s")
    if failed:
        # a figure crash must fail the process (CI smokes this path)
        raise SystemExit(f"FAILED figures: {', '.join(failed)}")


if __name__ == "__main__":
    main()
