"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs  = per-device trip-corrected dot FLOPs x chips (launch/hlo_analysis)
HLO_bytes  = per-device (args + outputs + 2*temps - aliases - CPU-upcast
             artifacts) x chips — every resident input buffer is streamed at
             least once per step, outputs written once, temps written+read.
collective_bytes = trip-corrected sum of collective result sizes x chips.

MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (inference);
the ratio MODEL_FLOPS / HLO_FLOPs exposes replicated/redundant compute.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, List, Optional

from repro.hw import TPU_V5E

RESULTS = Path(__file__).resolve().parents[1] / "dryrun_results"


def cell_roofline(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok") or rec.get("kind") == "colocated":
        return None
    chips = rec["chips"]
    chip = TPU_V5E
    m = rec["memory"]
    upcast = m.get("cpu_bf16_upcast_bytes", 0)
    temp_adj = max(m["temp_size_in_bytes"] - upcast,
                   m.get("analytic_activation_bytes", 0))
    hbm_bytes_dev = (m["argument_size_in_bytes"] + m["output_size_in_bytes"]
                     - m["alias_size_in_bytes"] + 2 * temp_adj)
    hbm_bytes = max(hbm_bytes_dev, 0) * chips
    flops = rec["hlo"]["dot_flops"] * chips
    coll = rec["hlo"]["collective_bytes"].get(
        "total_tpu", rec["hlo"]["collective_bytes"]["total"]) * chips

    t_comp = flops / (chips * chip.peak_flops_bf16)
    t_mem = hbm_bytes / (chips * chip.hbm_bw)
    t_coll = coll / (chips * chip.ici_bw_per_link)
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    model_flops = rec.get("model_flops", 0.0)
    bound = max(t_comp, t_mem, t_coll)
    # roofline fraction: useful work per achievable second at the binding
    # resource (1.0 = the step could not be faster on this hardware)
    useful_t = model_flops / (chips * chip.peak_flops_bf16)
    frac = useful_t / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom[1],
        "hlo_flops": flops, "hbm_bytes": hbm_bytes,
        "collective_bytes": coll,
        "model_flops": model_flops,
        "useful_ratio": model_flops / flops if flops else 0.0,
        "roofline_frac": frac,
        "resident_gib": m.get("resident_tpu_bytes", 0) / 2 ** 30,
    }


def load_all(mesh: str = "single") -> List[Dict]:
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        r = cell_roofline(json.loads(f.read_text()))
        if r:
            rows.append(r)
    return rows


def fmt_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'dominant':>10s} {'useful':>7s} "
           f"{'roofl%':>7s} {'res GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{r['compute_s']*1e3:9.2f} {r['memory_s']*1e3:9.2f} "
            f"{r['collective_s']*1e3:9.2f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.2f} {r['roofline_frac']*100:6.1f}% "
            f"{r['resident_gib']:8.2f}")
    return "\n".join(lines)


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    rows = load_all(mesh)
    if not rows:
        print("no dry-run results found — run launch/dryrun.py first")
        return
    print(fmt_table(rows))
    print()
    # CSV for run.py
    for r in rows:
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"roofline,{r['arch']}__{r['shape']}__{mesh},"
              f"{bound*1e6:.1f},{r['dominant']}|frac={r['roofline_frac']:.3f}"
              f"|useful={r['useful_ratio']:.3f}")


if __name__ == "__main__":
    main()
