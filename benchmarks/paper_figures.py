"""Paper-figure reproductions (one function per figure/table).

All cost-model/simulator driven (no TPU); each prints CSV rows
``name,us_per_call,derived`` that benchmarks/run.py aggregates.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs import get_config
from repro.core.costmodel import (CostModel, InstanceSpec, MXU_EFF, BW_EFF)
from repro.core.predictor import TwoStageLatencyPredictor
from repro.core.simulator import SimConfig, simulate
from repro.hw import ADA6000, TPU_V5E
from repro.serving.request import Request
from repro.serving.trace import TraceConfig, controlled_load, generate

LLAMA = get_config("llama3-8b")
QWEN = get_config("qwen2.5-7b")


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _clone(reqs):
    return [Request(rid=r.rid, arrival=r.arrival, prompt_len=r.prompt_len,
                    max_new_tokens=r.max_new_tokens) for r in reqs]


# Fig. 1 — prefill throughput flattens with bs; decode keeps scaling -------
def fig01_phase_throughput():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), noise_sigma=0)
    for seqlen in (128, 1024):
        tp_prev = 0.0
        flat_bs = None
        for bs in (1, 2, 4, 8, 16, 32, 64, 128, 256):
            t_pref = cm.prefill_latency(seqlen, bs)
            thr_pref = bs * seqlen / t_pref
            t_dec = cm.decode_solo(bs, seqlen, noisy=False)
            thr_dec = bs / t_dec
            _row(f"fig01.prefill.s{seqlen}.bs{bs}", t_pref * 1e6,
                 f"tok_per_s={thr_pref:.0f}")
            _row(f"fig01.decode.s{seqlen}.bs{bs}", t_dec * 1e6,
                 f"tok_per_s={thr_dec:.0f}")
            if flat_bs is None and tp_prev and thr_pref < tp_prev * 1.05:
                flat_bs = bs
            tp_prev = thr_pref
        _row(f"fig01.summary.s{seqlen}", 0,
             f"prefill_flattens_at_bs={flat_bs}")


# Fig. 3 — decode batch size under the trace -------------------------------
def fig03_trace_batchsize():
    reqs = generate(TraceConfig(duration_s=120, mean_rps=6.0, seed=0))
    res = simulate(LLAMA, LLAMA, _clone(reqs), SimConfig(mode="harli",
                                                         seed=0))
    bs = np.array([b for _, b in res.batch_timeline])
    _row("fig03.decode_bs", 0,
         f"mean={bs.mean():.1f}|p5={np.percentile(bs,5):.0f}"
         f"|p95={np.percentile(bs,95):.0f}|max={bs.max()}")


# Fig. 4 — decode-phase utilization (memory-bound, compute idle) -----------
def fig04_decode_utilization():
    for chip, name in ((ADA6000, "ada6000"), (TPU_V5E, "v5e")):
        cm = CostModel(LLAMA, InstanceSpec(chip=chip, tp=1 if
                                           chip is ADA6000 else 2),
                       noise_sigma=0)
        sms, bws = [], []
        for bs in (1, 4, 16, 64):
            for s in (128, 512, 1024):
                sm, bw = cm.decode_utilization(bs, s)
                sms.append(sm)
                bws.append(bw)
        _row(f"fig04.util.{name}", 0,
             f"mean_bw_util={np.mean(bws):.2f}|mean_compute_util="
             f"{np.mean(sms):.2f}")


# Fig. 5 — co-location potential (fwd-only ft1 / bwd-only ft2) --------------
def fig05_colocation_potential():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), noise_sigma=0)
    qos = 0.040
    u_avg = cm.avg_unit_work(2, 1024)
    for ft_name, backward in (("ft1_fwd", False), ("ft2_bwd", True)):
        best = 0.0
        u_dir = cm.unit_work(2, 1024, backward)
        # colocated_round schedules avg-units; convert to directional units
        conv = u_avg.flops / u_dir.flops
        for bs in (4, 16, 64):
            for s in (128, 1024):
                solo_u = cm.unit_solo(2, 1024, backward, noisy=False)
                base_rate = 1.0 / solo_u          # units/s on a dedicated chip
                # manually tune k to the QoS limit (paper §2.2.2 setup)
                k_best, rate = 0, 0.0
                for k in range(1, 20):
                    t = cm.colocated_round(bs, s, k, 2, 1024, noisy=False)
                    if t > qos:
                        break
                    k_best, rate = k, k * conv / t
                # colocated instance also still serves; improvement counts
                # harvested throughput relative to a dedicated ft chip
                imp = rate / base_rate
                best = max(best, imp)
                _row(f"fig05.{ft_name}.bs{bs}.s{s}", 0,
                     f"k={k_best}|harvested_frac={imp:.2f}")
        _row(f"fig05.{ft_name}.best", 0, f"max_harvested_frac={best:.2f}")


# Fig. 8 — solo decode latency vs (bs, seqlen) ------------------------------
def fig08_solo_latency():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), noise_sigma=0)
    for bs in (1, 4, 16, 64):
        lat = [cm.decode_solo(bs, s, noisy=False) for s in
               (64, 128, 256, 512)]
        slope = (lat[-1] - lat[0]) / (512 - 64)
        _row(f"fig08.bs{bs}", lat[-1] * 1e6,
             f"lat_ms@512={lat[-1]*1e3:.2f}|linear_slope_us_per_tok="
             f"{slope*1e6:.3f}")


# Fig. 9 — solo latency vs quantum (sublinear scaling) ----------------------
def fig09_quantum_scaling():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), noise_sigma=0)
    for bs, s in ((4, 256), (16, 256), (64, 512)):
        lats = {q: cm.decode_solo(bs, s, quantum=q / 10, noisy=False)
                for q in range(1, 11)}
        _row(f"fig09.bs{bs}.s{s}", lats[10] * 1e6,
             f"lat@10%={lats[1]*1e3:.1f}ms|lat@50%={lats[5]*1e3:.1f}ms"
             f"|lat@100%={lats[10]*1e3:.1f}ms")


# Fig. 10 — colo latency vs finetune quantum (linear slopes) ----------------
def fig10_colo_latency():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), noise_sigma=0)
    for bs in (4, 16, 64):
        lats = [cm.colocated_round(bs, 256, k, 2, 1024, noisy=False)
                for k in range(1, 10)]
        slopes = np.diff(lats)
        _row(f"fig10.bs{bs}", lats[-1] * 1e6,
             f"slope_ms_per_unit={np.mean(slopes)*1e3:.2f}"
             f"|slope_cv={np.std(slopes)/max(np.mean(slopes),1e-12):.2f}")


# Fig. 11 — headline: throughput + QoS across pairs and modes ---------------
def fig11_throughput_qos(duration_s: float = 120.0):
    pairs = [("llama3-8b", "llama3-8b"), ("llama3-8b", "qwen2.5-7b"),
             ("qwen2.5-7b", "llama3-8b"), ("qwen2.5-7b", "qwen2.5-7b")]
    base = generate(TraceConfig(duration_s=duration_s, mean_rps=6.0, seed=1))
    gains_sep, gains_sta = [], []
    for inf_name, ft_name in pairs:
        cfg_i, cfg_f = get_config(inf_name), get_config(ft_name)
        out = {}
        for mode in ("separate", "static", "harli"):
            t0 = time.time()
            res = simulate(cfg_i, cfg_f, _clone(base),
                           SimConfig(mode=mode, seed=2))
            out[mode] = res
            p99 = np.percentile(res.tpot, 99) * 1e3 if res.tpot else 0
            _row(f"fig11.{inf_name[:5]}-{ft_name[:5]}.{mode}",
                 (time.time() - t0) * 1e6,
                 f"ft_tp={res.ft_throughput:.2f}|tpot_p99_ms={p99:.1f}"
                 f"|qos_viol={res.qos_violation_frac*100:.2f}%"
                 f"|done={res.completed}")
        g_sep = out["harli"].ft_throughput / max(
            out["separate"].ft_throughput, 1e-9) - 1
        g_sta = out["harli"].ft_throughput / max(
            out["static"].ft_throughput, 1e-9) - 1
        gains_sep.append(g_sep)
        gains_sta.append(g_sta)
        _row(f"fig11.{inf_name[:5]}-{ft_name[:5]}.gain", 0,
             f"vs_separate={g_sep*100:+.1f}%|vs_static={g_sta*100:+.1f}%")
    _row("fig11.summary", 0,
         f"avg_vs_separate={np.mean(gains_sep)*100:+.1f}%"
         f"|max_vs_separate={np.max(gains_sep)*100:+.1f}%"
         f"|avg_vs_static={np.mean(gains_sta)*100:+.1f}%"
         f"|paper=+46.2%_avg_+92.0%_max")


# Fig. 12 — predictor error distributions -----------------------------------
def fig12_predictor_error():
    for name, cfg in (("L", LLAMA), ("Q", QWEN)):
        cm = CostModel(cfg, InstanceSpec(tp=2), seed=3)
        pred = TwoStageLatencyPredictor(k_max=10)
        rep = pred.fit_from_costmodel(cm)
        _row(f"fig12.stage1-{name}", rep.solo_fit_s * 1e6,
             f"mean_err={rep.solo_mean_err*100:.1f}%"
             f"|max_err={rep.solo_max_err*100:.1f}%|paper<=6%")
        _row(f"fig12.stage2-{name}{name}", rep.colo_fit_s * 1e6,
             f"mean_err={rep.colo_mean_err*100:.1f}%"
             f"|max_err={rep.colo_max_err*100:.1f}%|paper<=5%"
             f"|eq3_form_under_fusion={rep.colo_paper_mean_err*100:.0f}%")


# Fig. 13 — memory usage + window timeline (§8.5 controlled load) -----------
def fig13_memory_timeline():
    reqs = controlled_load(phases=((8, 20.0), (42, 20.0), (24, 20.0)))
    res = simulate(LLAMA, LLAMA, reqs, SimConfig(mode="harli", seed=4))
    tl = res.memory_timeline
    if not tl:
        _row("fig13.memory", 0, "no_timeline")
        return
    kv = np.array([s["kv_bytes"] for s in tl]) / 2 ** 30
    win = np.array([s["window_bytes"] for s in tl]) / 2 ** 30
    t = np.array([s["t"] for s in tl])
    for lo, hi, tag in ((0, 20, "light"), (20, 40, "heavy"),
                        (40, 70, "medium")):
        m = (t >= lo) & (t < hi)
        if m.any():
            _row(f"fig13.phase.{tag}", 0,
                 f"kv_gib={kv[m].mean():.2f}|window_gib={win[m].mean():.2f}")
    corr = np.corrcoef(kv, win)[0, 1] if len(kv) > 3 else 0.0
    _row("fig13.summary", 0,
         f"kv_window_anticorrelation={corr:+.2f} (window yields to KV)")


# Fig. 14 — scheduler quantum + latency timeline ----------------------------
def fig14_scheduler_timeline():
    reqs = controlled_load(phases=((8, 15.0), (42, 15.0), (24, 15.0)))
    res = simulate(LLAMA, LLAMA, reqs, SimConfig(mode="harli", seed=5))
    qt = [q for q in res.quantum_timeline if q[3] > 0]   # decode rounds only
    ks = np.array([k for _, k, _, _ in qt])
    lat = np.array([l for _, _, l, _ in qt])
    preempt = float(np.mean(ks == 0))
    _row("fig14.scheduler", 0,
         f"mean_k={ks.mean():.1f}|preempt_frac={preempt:.2f}"
         f"|mean_round_ms={lat.mean()*1e3:.1f}"
         f"|p99_round_ms={np.percentile(lat,99)*1e3:.1f}")


# §8.7 — Harli-TP (shared base weights) --------------------------------------
def sec87_tp_mode(duration_s: float = 90.0):
    # heavier prompts squeeze the unified pool so the non-shared window
    # actually swaps (the regime §8.7 targets)
    base = generate(TraceConfig(duration_s=duration_s, mean_rps=7.0,
                                prompt_median=2048, seed=6))
    res_plain = simulate(LLAMA, LLAMA, _clone(base),
                         SimConfig(mode="harli", seed=7,
                                   share_base_weights=False))
    res_tp = simulate(LLAMA, LLAMA, _clone(base),
                      SimConfig(mode="harli", seed=7,
                                share_base_weights=True))
    gain = res_tp.ft_throughput / max(res_plain.ft_throughput, 1e-9) - 1
    _row("sec87.harli", 0, f"ft_tp={res_plain.ft_throughput:.2f}")
    _row("sec87.harli_tp_shared", 0,
         f"ft_tp={res_tp.ft_throughput:.2f}|gain={gain*100:+.1f}%"
         f"|paper=+10.2%")


# §8.8 — overheads ------------------------------------------------------------
def sec88_overhead():
    cm = CostModel(LLAMA, InstanceSpec(tp=2), seed=8)
    pred = TwoStageLatencyPredictor(k_max=10)
    rep = pred.fit_from_costmodel(cm)
    _row("sec88.fit", (rep.solo_fit_s + rep.colo_fit_s) * 1e6,
         f"solo_samples={rep.solo_samples}|colo_samples={rep.colo_samples}")
    _row("sec88.predict", pred.predict_latency_us(), "paper~5us")
    # small-tensor pool fragmentation under a synthetic allocation storm
    from repro.core.buddy import BuddyAllocator
    rng = np.random.default_rng(0)
    b = BuddyAllocator(256 * 1024 * 1024)
    live = []
    for _ in range(5000):
        if live and rng.random() < 0.45:
            b.freeb(live.pop(rng.integers(len(live))))
        else:
            off = b.alloc(int(rng.lognormal(10, 1.5)))
            if off is not None:
                live.append(off)
    _row("sec88.fragmentation", 0,
         f"frag_mb={b.fragmentation_bytes/2**20:.1f}|paper<100MB"
         f"|peak_mb={b.peak_bytes/2**20:.1f}")


# Beyond-paper: cluster goodput under the two-tier routing plane
# (core/cluster.py) across the multi-tenant scenario presets. Goodput is
# DistServe's SLO-attaining throughput; harli must hold it while adding
# finetune throughput the separate fleet can't match. Spec-driven: the
# whole experiment is one ExperimentSpec (repro.core.api), same entry
# point examples/cluster_sim.py --spec uses.
def cluster_goodput(duration_s: float = 90.0):
    from repro.core.api import ExperimentSpec
    from repro.core.cluster import ClusterConfig

    for scen in ("steady", "spike"):
        spec = ExperimentSpec(
            name=f"cluster_goodput_{scen}", scenario=scen,
            duration_s=duration_s, mean_rps=10.0, seed=20,
            sim=SimConfig(seed=22), cluster=ClusterConfig(n_initial=2))
        for mode in ("separate", "harli"):
            t0 = time.time()
            res = spec.with_mode(mode).run()
            s = res.stats
            _row(f"cluster_goodput,{scen},{mode}",
                 (time.time() - t0) * 1e6,
                 f"goodput={s.goodput:.2f}|thr={s.throughput:.2f}"
                 f"|attain={s.slo_attainment:.3f}"
                 f"|ft={res.ft_throughput:.2f}"
                 f"|fleet={res.final_fleet}/{res.peak_fleet}"
                 f"|prefill={res.final_prefill}/{res.peak_prefill}")


# Beyond-paper: fleet timeline (serving / colocated / prefill workers) vs
# windowed goodput over time, harli vs separate, under the spike scenario —
# the ROADMAP "paper-figures plot" item. Writes a PNG next to the CSV rows
# when matplotlib is available; the CSV timeline is always printed.
def cluster_fleet_timeline(duration_s: float = 90.0):
    import os

    from repro.core.api import ExperimentSpec
    from repro.core.cluster import ClusterConfig, ClusterSim
    from repro.core.router import RouterConfig, request_slo

    win = max(duration_s / 18.0, 2.5)       # goodput window (s)
    base = ExperimentSpec(
        name="cluster_fleet_timeline", scenario="spike",
        duration_s=duration_s, mean_rps=10.0, seed=30,
        sim=SimConfig(seed=32),
        cluster=ClusterConfig(
            n_initial=2,
            router=RouterConfig(policy="predicted_latency")))
    series = {}
    for mode in ("separate", "harli"):
        spec = base.with_mode(mode)
        cs = ClusterSim(LLAMA, LLAMA, spec.sim, spec.cluster)
        res = cs.run(spec.requests(), duration_s)
        finishes = []
        for inst in cs.router.all_instances():
            for r in inst.all_reqs:
                if r.finish < 0 or not r.token_times:
                    continue
                ttft_ok, tpot_ok, _, _ = request_slo(r, cs.router.cfg)
                if ttft_ok and tpot_ok:
                    finishes.append(r.finish)
        finishes = np.asarray(sorted(finishes))
        edges = np.arange(0.0, duration_s + win, win)
        good = np.histogram(finishes, bins=edges)[0] / win
        series[mode] = dict(res=res, edges=edges, good=good)
        for t, n_serv, n_colo in res.fleet_timeline[::5]:
            pf = 0
            for tp, n_pf, _ in res.prefill_timeline:
                if tp <= t:
                    pf = n_pf
            _row(f"cluster_fleet_timeline,{mode},t={t:.0f}", 0,
                 f"serving={n_serv}|colocated={n_colo}|prefill={pf}")
        _row(f"cluster_fleet_timeline,{mode}.goodput", 0,
             f"peak={good.max():.2f}|mean={good.mean():.2f}|window_s={win:g}")

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        _row("cluster_fleet_timeline.png", 0, "skipped_no_matplotlib")
        return

    # palette: categorical slots 1-3 for the fleet series, violet for the
    # single-series goodput panels; light surface, recessive grid
    C = {"serving": "#2a78d6", "colocated": "#eb6834",
         "prefill": "#1baf7a", "goodput": "#4a3aa7",
         "ink": "#0b0b0b", "ink2": "#52514e", "grid": "#e4e3df",
         "surface": "#fcfcfb"}
    fig, axes = plt.subplots(2, 2, figsize=(9.6, 5.4), sharex=True,
                             facecolor=C["surface"])
    for col, mode in enumerate(("harli", "separate")):
        res = series[mode]["res"]
        ax = axes[0][col]
        t = [p[0] for p in res.fleet_timeline]
        ends = []                    # (end value, key) for label dodging
        for key, vals in (
                ("serving", [p[1] for p in res.fleet_timeline]),
                ("colocated", [p[2] for p in res.fleet_timeline]),
                ("prefill", [p[1] for p in res.prefill_timeline])):
            if vals:
                ax.plot(t[:len(vals)], vals, drawstyle="steps-post",
                        lw=2, color=C[key], label=key)
                ends.append((vals[-1], t[len(vals) - 1], key))
        # direct end labels, dodged vertically when lines coincide
        ends.sort()
        for i, (v, tx, key) in enumerate(ends):
            prior = [e for e in ends[:i] if e[0] == v]
            ax.annotate(key, (tx, v),
                        xytext=(4, 9 * len(prior)),
                        textcoords="offset points",
                        fontsize=8, color=C[key], va="center")
        ax.set_title(f"{mode} — fleet size", fontsize=10, color=C["ink"])
        ax.set_ylabel("instances / workers", fontsize=8.5)
        ax.legend(fontsize=8, frameon=False, loc="upper left")
        ax2 = axes[1][col]
        edges, good = series[mode]["edges"], series[mode]["good"]
        ax2.plot(edges[:-1], good, drawstyle="steps-post", lw=2,
                 color=C["goodput"])
        ax2.set_title(f"{mode} — goodput (SLO-attaining req/s, "
                      f"{win:g}s windows)", fontsize=10, color=C["ink"])
        ax2.set_xlabel("time (s)", fontsize=8.5)
        ax2.set_ylabel("req/s", fontsize=8.5)
    for ax in axes.flat:
        ax.set_facecolor(C["surface"])
        ax.grid(color=C["grid"], lw=0.6)
        ax.tick_params(labelsize=8, colors=C["ink2"])
        for s in ax.spines.values():
            s.set_color(C["grid"])
    fig.suptitle("Two-tier cluster under a flash crowd: fleet timeline vs "
                 "goodput", fontsize=11, color=C["ink"])
    fig.tight_layout()
    out_dir = os.path.join(os.path.dirname(__file__), "figures")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "cluster_fleet_timeline.png")
    fig.savefig(path, dpi=150, facecolor=C["surface"])
    plt.close(fig)
    _row("cluster_fleet_timeline.png", 0, path)


# Beyond-paper: the three prefill deployment modes (chained / pooled /
# chunked, docs/cluster.md) head-to-head on the spike scenario — where
# does prefill work belong when decode instances are deliberately kept
# busy with PEFT finetuning? CSV rows report goodput, TTFT/TPOT p99 and
# total hardware; the PNG bars make the tradeoff visible. The chunked
# column must hold the TPOT SLO (the QoS price check) while using no
# prefill tier at all.
def cluster_prefill_modes(duration_s: float = 90.0):
    import os

    from repro.core.api import ExperimentSpec
    from repro.core.cluster import ClusterConfig
    from repro.core.prefill_pool import PrefillPoolConfig
    from repro.core.router import RouterConfig

    rcfg = RouterConfig()
    tpot_limit_ms = rcfg.tpot_slo_s * rcfg.tpot_slack * 1e3
    modes = {
        "chained": dict(prefill_mode="chained", prefill=None),
        "pooled": dict(prefill_mode="pooled",
                       prefill=PrefillPoolConfig()),
        "chunked": dict(prefill_mode="chunked", prefill=None),
    }
    # prefill-side hardware peak per mode: pool workers (pooled), one
    # implicit serialized-prefill partner per peak instance (chained),
    # none (chunked — prefill rides the decode fleet). One definition for
    # both the CSV rows and the PNG panel.
    def prefill_peak(name, res):
        return {"pooled": res.peak_prefill, "chained": res.peak_fleet,
                "chunked": 0}[name]

    out = {}
    for name, kw in modes.items():
        res = ExperimentSpec(
            name=f"cluster_prefill_modes_{name}", scenario="spike",
            duration_s=duration_s, mean_rps=10.0, seed=40,
            sim=SimConfig(mode="harli", seed=42),
            cluster=ClusterConfig(n_initial=2, router=rcfg, **kw)).run()
        out[name] = res
        s = res.stats
        pf = prefill_peak(name, res)
        _row(f"cluster_prefill_modes,{name}", 0,
             f"goodput={s.goodput:.2f}|thr={s.throughput:.2f}"
             f"|attain={s.slo_attainment:.3f}"
             f"|ttft_p99={s.ttft_p99:.2f}"
             f"|tpot_p99_ms={s.tpot_p99*1e3:.1f}"
             f"|tpot_slo_ok={int(s.tpot_p99*1e3 <= tpot_limit_ms)}"
             f"|ft={res.ft_throughput:.2f}"
             f"|decode_peak={res.peak_fleet}|prefill_peak={pf}"
             f"|hw_peak={res.peak_fleet + pf}")

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        _row("cluster_prefill_modes.png", 0, "skipped_no_matplotlib")
        return

    # same visual system as cluster_fleet_timeline: categorical slots for
    # the modes, light surface, recessive grid
    C = {"chained": "#2a78d6", "pooled": "#eb6834", "chunked": "#1baf7a",
         "ink": "#0b0b0b", "ink2": "#52514e", "grid": "#e4e3df",
         "surface": "#fcfcfb", "slo": "#b3261e"}
    panels = [
        ("goodput (req/s)", lambda n: out[n].stats.goodput, None),
        ("TTFT p99 (s)", lambda n: out[n].stats.ttft_p99, rcfg.ttft_slo_s),
        ("TPOT p99 (ms)", lambda n: out[n].stats.tpot_p99 * 1e3,
         tpot_limit_ms),
        ("peak hardware (instances)",
         lambda n: out[n].peak_fleet + prefill_peak(n, out[n]), None),
    ]
    fig, axes = plt.subplots(1, 4, figsize=(10.8, 3.1),
                             facecolor=C["surface"])
    names = list(modes)
    for ax, (title, get, slo) in zip(axes, panels):
        vals = [get(n) for n in names]
        ax.bar(range(len(names)), vals, 0.62,
               color=[C[n] for n in names])
        for i, v in enumerate(vals):
            ax.annotate(f"{v:.1f}", (i, v), xytext=(0, 3),
                        textcoords="offset points", ha="center",
                        fontsize=8, color=C["ink2"])
        if slo is not None:
            ax.axhline(slo, color=C["slo"], lw=1.1, ls="--")
            ax.annotate("SLO", (len(names) - 0.5, slo), xytext=(2, 2),
                        textcoords="offset points", fontsize=7.5,
                        color=C["slo"])
        ax.set_title(title, fontsize=9.5, color=C["ink"])
        ax.set_xticks(range(len(names)))
        ax.set_xticklabels(names, fontsize=8.5)
        ax.set_facecolor(C["surface"])
        ax.grid(axis="y", color=C["grid"], lw=0.6)
        ax.set_axisbelow(True)
        ax.tick_params(labelsize=8, colors=C["ink2"])
        for sp in ax.spines.values():
            sp.set_color(C["grid"])
    fig.suptitle("Prefill deployment modes under a flash crowd "
                 "(spike scenario, harli fleet)", fontsize=10.5,
                 color=C["ink"])
    fig.tight_layout()
    out_dir = os.path.join(os.path.dirname(__file__), "figures")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "cluster_prefill_modes.png")
    fig.savefig(path, dpi=150, facecolor=C["surface"])
    plt.close(fig)
    _row("cluster_prefill_modes.png", 0, path)


# Beyond-paper: cache-aware routing (the control-plane API's registered
# plugin, core/policies/cache_aware.py) vs session_affinity vs
# least_loaded on the session_heavy scenario — the config pinned in
# examples/specs/session_heavy_cache_aware.json. cache_aware must beat
# session_affinity on TTFT p99 at equal goodput: the sticky map is
# load-blind (hot sessions pile onto one instance until the overflow
# cliff), while the plugin reads every instance's PrefixCache and trades
# cached-prefix savings against queue depth continuously.
def cluster_cache_aware(duration_s: float = 60.0):
    import dataclasses
    import os

    from repro.core.api import ExperimentSpec

    spec = ExperimentSpec.load(os.path.join(
        os.path.dirname(__file__), "..", "examples", "specs",
        "session_heavy_cache_aware.json"))
    spec = dataclasses.replace(spec, duration_s=duration_s)
    out = {}
    for policy in ("least_loaded", "session_affinity", "cache_aware"):
        run = dataclasses.replace(
            spec, cluster=dataclasses.replace(
                spec.cluster, router=dataclasses.replace(
                    spec.cluster.router, policy=policy)))
        t0 = time.time()
        res = run.run()
        out[policy] = res
        s = res.stats
        tot = max(res.prefix_hits + res.prefix_misses, 1)
        _row(f"cluster_cache_aware,{policy}", (time.time() - t0) * 1e6,
             f"ttft_p99={s.ttft_p99:.3f}|goodput={s.goodput:.2f}"
             f"|attain={s.slo_attainment:.3f}"
             f"|hits={res.prefix_hits}|hit_rate={res.prefix_hits/tot:.3f}"
             f"|hit_tokens={res.prefix_hit_tokens}")
    aware, sticky = out["cache_aware"].stats, out["session_affinity"].stats
    _row("cluster_cache_aware.summary", 0,
         f"aware_vs_sticky_ttft_p99="
         f"{aware.ttft_p99/max(sticky.ttft_p99, 1e-9):.2f}x"
         f"|goodput_ratio={aware.goodput/max(sticky.goodput, 1e-9):.2f}x"
         f"|win={int(aware.ttft_p99 < sticky.ttft_p99 and aware.goodput >= sticky.goodput)}")


# Beyond-paper: goodput under churn — the failure/preemption layer
# (serving/trace.py FailureSchedule + ClusterSim._apply_failures). Sweep
# the seeded Poisson kill rate and compare harli co-location against the
# separate fleet on goodput and tail latency while instances die
# mid-epoch: in-flight decodes lose their KV and re-prefill through the
# router, pooled prefill batches requeue, the autoscaler replaces
# capacity, and colocated finetune jobs roll back to their last
# checkpoint commit. Rate 0 runs failures=None — the stable-fleet path —
# so the sweep's origin is bit-identical to every other cluster figure.
# The harli+mig series re-runs harli with live KV migration armed
# (docs/cluster.md "Surviving preemption"): same kills, but warned
# instances pre-copy their KV instead of re-prefilling losers from
# scratch.
def cluster_churn(duration_s: float = 90.0):
    import os

    from repro.core.api import ExperimentSpec
    from repro.core.cluster import ClusterConfig, KVMigrationConfig
    from repro.core.prefill_pool import PrefillPoolConfig
    from repro.core.router import RouterConfig
    from repro.serving.trace import FailureConfig

    rcfg = RouterConfig()
    rates = (0.0, 0.5, 1.0, 2.0, 4.0)
    arms = (("harli", "harli", None), ("separate", "separate", None),
            ("harli+mig", "harli", KVMigrationConfig()))
    out = {}
    for rate in rates:
        failures = None if rate == 0 else FailureConfig(
            rate_per_min=rate, warning_s=5.0,
            checkpoint_interval_s=15.0, seed=9)
        for arm, sim_mode, migration in arms:
            if rate == 0 and arm == "harli+mig":
                # no kills -> migration never fires; bit-identical to
                # plain harli (pinned in tests/test_survivability.py)
                out[(arm, rate)] = out[("harli", rate)]
                continue
            t0 = time.time()
            res = ExperimentSpec(
                name=f"cluster_churn_{sim_mode}_{rate:g}",
                scenario="steady", duration_s=duration_s, mean_rps=10.0,
                seed=40, sim=SimConfig(mode=sim_mode, seed=42),
                cluster=ClusterConfig(
                    n_initial=3, router=rcfg, prefill_mode="pooled",
                    prefill=PrefillPoolConfig(),
                    failures=failures,
                    migration=migration if rate else None)).run()
            out[(arm, rate)] = res
            s = res.stats
            _row(f"cluster_churn,{arm},rate{rate:g}",
                 (time.time() - t0) * 1e6,
                 f"goodput={s.goodput:.2f}|thr={s.throughput:.2f}"
                 f"|attain={s.slo_attainment:.3f}"
                 f"|ttft_p99={s.ttft_p99:.2f}"
                 f"|tpot_p99_ms={s.tpot_p99*1e3:.1f}"
                 f"|kills={res.failures}|warned={res.preemptions}"
                 f"|requeued={res.requeued_requests}"
                 f"|requeue_rejected={res.requeue_rejected}"
                 f"|migrated={res.migrated_requests}"
                 f"|mig_kv_tokens={res.migrated_kv_tokens}"
                 f"|mig_reprefills={res.migration_reprefills}"
                 f"|ft={res.ft_throughput:.2f}"
                 f"|ft_lost_iters={res.ft_lost_iterations:.1f}"
                 f"|ckpt_commits={res.checkpoint_commits}")
    for rate in rates[1:]:
        h = out[("harli", rate)]
        s = out[("separate", rate)]
        m = out[("harli+mig", rate)]
        _row(f"cluster_churn.summary,rate{rate:g}", 0,
             f"goodput_ratio="
             f"{h.stats.goodput/max(s.stats.goodput, 1e-9):.2f}x"
             f"|ft_ratio={h.ft_throughput/max(s.ft_throughput, 1e-9):.2f}x"
             f"|mig_vs_reprefill="
             f"{m.stats.goodput/max(h.stats.goodput, 1e-9):.2f}x")

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        _row("cluster_churn.png", 0, "skipped_no_matplotlib")
        return

    C = {"harli": "#2a78d6", "separate": "#eb6834",
         "harli+mig": "#1baf7a", "ink": "#0b0b0b",
         "ink2": "#52514e", "grid": "#e4e3df", "surface": "#fcfcfb",
         "slo": "#b3261e"}
    tpot_limit_ms = rcfg.tpot_slo_s * rcfg.tpot_slack * 1e3
    panels = [
        ("goodput (req/s)", lambda r: r.stats.goodput, None),
        ("TTFT p99 (s)", lambda r: r.stats.ttft_p99, rcfg.ttft_slo_s),
        ("TPOT p99 (ms)", lambda r: r.stats.tpot_p99 * 1e3,
         tpot_limit_ms),
        # not ft_lost_iterations: with warnings on, preemption notices
        # checkpoint before dying, so iters lost stays ~0 — the churn
        # cost lands on finetune throughput (rollback + commit stalls +
        # respawned instances warming up)
        ("finetune iters/s x batch", lambda r: r.ft_throughput, None),
    ]
    fig, axes = plt.subplots(1, 4, figsize=(10.8, 3.1),
                             facecolor=C["surface"])
    for ax, (title, get, slo) in zip(axes, panels):
        for arm, _, _ in arms:
            ax.plot(rates, [get(out[(arm, r)]) for r in rates],
                    marker="o", ms=3.5, lw=1.4, color=C[arm],
                    label=arm)
        if slo is not None:
            ax.axhline(slo, color=C["slo"], lw=1.1, ls="--")
        ax.set_title(title, fontsize=9.5, color=C["ink"])
        ax.set_xlabel("kills / min", fontsize=8.5, color=C["ink2"])
        ax.set_facecolor(C["surface"])
        ax.grid(color=C["grid"], lw=0.6)
        ax.set_axisbelow(True)
        ax.tick_params(labelsize=8, colors=C["ink2"])
        for sp in ax.spines.values():
            sp.set_color(C["grid"])
    axes[0].legend(fontsize=8, frameon=False)
    fig.suptitle("Goodput under churn (steady scenario, pooled prefill, "
                 "seeded Poisson kills + 5s preemption warnings)",
                 fontsize=10.5, color=C["ink"])
    fig.tight_layout()
    out_dir = os.path.join(os.path.dirname(__file__), "figures")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "cluster_churn.png")
    fig.savefig(path, dpi=150, facecolor=C["surface"])
    plt.close(fig)
    _row("cluster_churn.png", 0, path)


# Beyond-paper: the survivability ladder — what each mitigation layer
# buys as spot churn climbs. Long contexts (2k-token prompts, 512-token
# median outputs) make re-prefill genuinely expensive, which is the
# regime live KV migration targets. Three arms per kill rate:
#   no-mitigation  — kills land with no warning (warning_s=0): no drain,
#                    no pre-kill checkpoint, full re-prefill
#   re-prefill     — the PR 6 default: 5s drain window, losers
#                    re-prefill from scratch
#   migrate+ladder — pre-copy KV migration racing the deadline plus the
#                    overload degradation ladder (breaker -> shed)
# The high-churn ordering (migrate+ladder > re-prefill > no-mitigation
# on goodput at equal-or-better TPOT p99) is pinned in
# tests/test_survivability.py.
def cluster_survivability(duration_s: float = 90.0):
    import os

    from repro.core.cluster import (ClusterConfig, DegradationConfig,
                                    KVMigrationConfig, simulate_cluster)
    from repro.core.prefill_pool import PrefillPoolConfig
    from repro.core.router import RouterConfig
    from repro.serving.trace import FailureConfig, TraceConfig

    rcfg = RouterConfig()
    rates = (0.0, 2.0, 5.0, 10.0)
    base = generate(TraceConfig(
        duration_s=duration_s, mean_rps=8.0, burstiness=0.8,
        rate_amplitude=0.1, prompt_median=2048, output_median=512,
        output_max=1024, seed=1))
    arms = {
        "no-mitigation": dict(warning_s=0.0, migration=None,
                              degradation=None),
        "re-prefill": dict(warning_s=5.0, migration=None,
                           degradation=None),
        "migrate+ladder": dict(warning_s=5.0,
                               migration=KVMigrationConfig(),
                               degradation=DegradationConfig()),
    }
    out = {}
    for rate in rates:
        for arm, kw in arms.items():
            if rate == 0 and arm != "re-prefill":
                continue
            failures = None if rate == 0 else FailureConfig(
                rate_per_min=rate, warning_s=kw["warning_s"],
                checkpoint_interval_s=15.0, seed=9)
            t0 = time.time()
            res = simulate_cluster(
                LLAMA, LLAMA, _clone(base), SimConfig(mode="harli",
                                                      seed=2),
                ClusterConfig(n_initial=3, router=rcfg,
                              prefill_mode="pooled",
                              prefill=PrefillPoolConfig(),
                              failures=failures,
                              migration=kw["migration"],
                              degradation=kw["degradation"]))
            out[(arm, rate)] = res
            s = res.stats
            _row(f"cluster_survivability,{arm},rate{rate:g}",
                 (time.time() - t0) * 1e6,
                 f"goodput={s.goodput:.2f}|attain={s.slo_attainment:.3f}"
                 f"|ttft_p99={s.ttft_p99:.2f}"
                 f"|tpot_p99_ms={s.tpot_p99*1e3:.1f}"
                 f"|kills={res.failures}"
                 f"|requeued={res.requeued_requests}"
                 f"|migrated={res.migrated_requests}"
                 f"|mig_kv_tokens={res.migrated_kv_tokens}"
                 f"|mig_reprefills={res.migration_reprefills}"
                 f"|shed={res.shed_requests}"
                 f"|shed_rejected={res.shed_rejected}"
                 f"|ladder_peak={res.ladder_peak}")
    # no kills: warning windows and migration never fire, and the ladder
    # thresholds are calibrated to stay disarmed on a healthy fleet —
    # all three arms share the rate-0 origin run
    for arm in arms:
        out.setdefault((arm, 0.0), out[("re-prefill", 0.0)])
    for rate in rates[1:]:
        none_ = out[("no-mitigation", rate)]
        rep = out[("re-prefill", rate)]
        mig = out[("migrate+ladder", rate)]
        _row(f"cluster_survivability.summary,rate{rate:g}", 0,
             f"reprefill_vs_none="
             f"{rep.stats.goodput/max(none_.stats.goodput, 1e-9):.2f}x"
             f"|mig_vs_reprefill="
             f"{mig.stats.goodput/max(rep.stats.goodput, 1e-9):.2f}x")

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        _row("cluster_survivability.png", 0, "skipped_no_matplotlib")
        return

    C = {"no-mitigation": "#b3261e", "re-prefill": "#eb6834",
         "migrate+ladder": "#1baf7a", "ink": "#0b0b0b",
         "ink2": "#52514e", "grid": "#e4e3df", "surface": "#fcfcfb",
         "slo": "#b3261e"}
    tpot_limit_ms = rcfg.tpot_slo_s * rcfg.tpot_slack * 1e3
    panels = [
        ("goodput (req/s)", lambda r: r.stats.goodput, None),
        ("TTFT p99 (s)", lambda r: r.stats.ttft_p99, rcfg.ttft_slo_s),
        ("TPOT p99 (ms)", lambda r: r.stats.tpot_p99 * 1e3,
         tpot_limit_ms),
    ]
    fig, axes = plt.subplots(1, 3, figsize=(9.0, 3.1),
                             facecolor=C["surface"])
    for ax, (title, get, slo) in zip(axes, panels):
        for arm in arms:
            ax.plot(rates, [get(out[(arm, r)]) for r in rates],
                    marker="o", ms=3.5, lw=1.4, color=C[arm], label=arm)
        if slo is not None:
            ax.axhline(slo, color=C["slo"], lw=1.1, ls="--")
        ax.set_title(title, fontsize=9.5, color=C["ink"])
        ax.set_xlabel("kills / min", fontsize=8.5, color=C["ink2"])
        ax.set_facecolor(C["surface"])
        ax.grid(color=C["grid"], lw=0.6)
        ax.set_axisbelow(True)
        ax.tick_params(labelsize=8, colors=C["ink2"])
        for sp in ax.spines.values():
            sp.set_color(C["grid"])
    axes[0].legend(fontsize=8, frameon=False)
    fig.suptitle("Surviving preemption: live KV migration + degradation "
                 "ladder vs re-prefill (long-context trace, harli fleet)",
                 fontsize=10.5, color=C["ink"])
    fig.tight_layout()
    out_dir = os.path.join(os.path.dirname(__file__), "figures")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "cluster_survivability.png")
    fig.savefig(path, dpi=150, facecolor=C["surface"])
    plt.close(fig)
    _row("cluster_survivability.png", 0, path)


# Beyond-paper: closing the finetune->serve loop. Each tenant's
# colocated finetune job publishes versioned LoRA adapters into the
# fleet registry (core/adapters.py); decode instances hot-load the
# stamped version on demand, with adapter weight bytes charged to the
# unified allocator and swap time priced by CostModel.adapter_load_time
# into the round the load lands in. Sweep the tenant count and compare:
#   continuous — harli continuous deployment: every publish_every_iters
#                finetune iterations per tenant publishes a new version
#   static     — the deploy-once baseline: publication frozen at v1
# The claim (pinned in tests/test_adapters.py): continuous deployment
# serves strictly more adapter versions while sustaining per-tenant
# TTFT/TPOT SLO attainment no worse than static — freshness is free
# because swaps are priced, placed with affinity, and charged against
# headroom the admission path already respects.
def cluster_adapter_serving(duration_s: float = 90.0):
    import os

    from repro.core.adapters import AdapterServingConfig, TenantConfig
    from repro.core.api import ExperimentSpec
    from repro.core.cluster import ClusterConfig
    from repro.core.prefill_pool import PrefillPoolConfig

    tenant_counts = (2, 4, 8)
    arms = (("continuous", True), ("static", False))
    out = {}
    for n in tenant_counts:
        w = [1.0 / (i + 1) for i in range(n)]
        tenants = tuple(TenantConfig(name=f"t{i}", weight=wi / sum(w))
                        for i, wi in enumerate(w))
        for arm, continuous in arms:
            t0 = time.time()
            res = ExperimentSpec(
                name=f"cluster_adapter_serving_{arm}_{n}",
                scenario="multi_tenant", duration_s=duration_s,
                mean_rps=8.0, seed=3, tenants=tenants,
                sim=SimConfig(mode="harli", seed=3),
                cluster=ClusterConfig(
                    n_initial=2, prefill_mode="pooled",
                    prefill=PrefillPoolConfig(),
                    adapters=AdapterServingConfig(
                        publish_every_iters=2.0,
                        continuous=continuous))).run()
            out[(arm, n)] = res
            s = res.stats
            tns = s.tenants.values()
            worst_ttft = min((t.ttft_attainment for t in tns), default=0)
            worst_tpot = min((t.tpot_attainment for t in tns), default=0)
            _row(f"cluster_adapter_serving,{arm},tenants{n}",
                 (time.time() - t0) * 1e6,
                 f"goodput={s.goodput:.2f}|attain={s.slo_attainment:.3f}"
                 f"|worst_tenant_ttft_att={worst_ttft:.3f}"
                 f"|worst_tenant_tpot_att={worst_tpot:.3f}"
                 f"|loads={res.adapter_loads}"
                 f"|evictions={res.adapter_evictions}"
                 f"|load_failures={res.adapter_load_failures}"
                 f"|swap_s={res.adapter_load_time_s:.2f}"
                 f"|published={res.adapter_versions_published}"
                 f"|served={res.adapter_versions_served}"
                 f"|ft={res.ft_throughput:.2f}")
    for n in tenant_counts:
        c, st = out[("continuous", n)], out[("static", n)]
        _row(f"cluster_adapter_serving.summary,tenants{n}", 0,
             f"attain_ratio={c.stats.slo_attainment / max(st.stats.slo_attainment, 1e-9):.3f}x"
             f"|versions_served={c.adapter_versions_served}"
             f"_vs_{st.adapter_versions_served}"
             f"|win={int(c.stats.slo_attainment >= st.stats.slo_attainment and c.adapter_versions_served > st.adapter_versions_served)}")

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        _row("cluster_adapter_serving.png", 0, "skipped_no_matplotlib")
        return

    C = {"continuous": "#2a78d6", "static": "#eb6834",
         "ink": "#0b0b0b", "ink2": "#52514e", "grid": "#e4e3df",
         "surface": "#fcfcfb"}
    panels = [
        ("SLO attainment", lambda r: r.stats.slo_attainment),
        ("worst-tenant TTFT attain",
         lambda r: min((t.ttft_attainment
                        for t in r.stats.tenants.values()), default=0)),
        ("adapter versions served",
         lambda r: r.adapter_versions_served),
        ("hot-loads (swaps)", lambda r: r.adapter_loads),
    ]
    fig, axes = plt.subplots(1, 4, figsize=(10.8, 3.1),
                             facecolor=C["surface"])
    for ax, (title, get) in zip(axes, panels):
        for arm, _ in arms:
            ax.plot(tenant_counts,
                    [get(out[(arm, n)]) for n in tenant_counts],
                    marker="o", ms=3.5, lw=1.4, color=C[arm], label=arm)
        ax.set_title(title, fontsize=9.5, color=C["ink"])
        ax.set_xlabel("tenants", fontsize=8.5, color=C["ink2"])
        ax.set_xticks(tenant_counts)
        ax.set_facecolor(C["surface"])
        ax.grid(color=C["grid"], lw=0.6)
        ax.set_axisbelow(True)
        ax.tick_params(labelsize=8, colors=C["ink2"])
        for sp in ax.spines.values():
            sp.set_color(C["grid"])
    axes[0].legend(fontsize=8, frameon=False)
    fig.suptitle("Serving what you finetune: continuous adapter "
                 "deployment vs static baseline (multi-tenant trace, "
                 "affinity-packed placement)",
                 fontsize=10.5, color=C["ink"])
    fig.tight_layout()
    out_dir = os.path.join(os.path.dirname(__file__), "figures")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "cluster_adapter_serving.png")
    fig.savefig(path, dpi=150, facecolor=C["surface"])
    plt.close(fig)
    _row("cluster_adapter_serving.png", 0, path)


# Beyond-paper: fleet-scale cache-aware routing from gossiped digests
# (core/gossip.py + core/policies/cache_aware_gossip.py) on the
# shared_prefix scenario, where sessions in the same group share a
# 384-token system prompt that only the cross-session radix tree
# (core/prefix_tree.py) can serve across sessions. Panel A sweeps fleet
# size at fixed per-instance load with three arms:
#   sync          — cache_aware, which peeks every instance's cache
#                   synchronously on each dispatch (O(fleet) peeks)
#   gossip        — cache_aware_gossip, scoring from staleness-bounded
#                   digests alone: zero synchronous peeks at dispatch
#   session-keyed — cache_aware with cross_session=False, the PR 4
#                   behaviour (no sharing between sessions)
# Acceptance (pinned in tests/test_prefix_gossip.py): at fleet >= 32
# gossip's TTFT p99 stays within 10% of sync with dispatch_peeks == 0,
# and beats session-keyed on TTFT p99 at equal goodput. Panel B holds
# the fleet at 16 and sweeps the gossip period with the staleness bound
# at 2x the period: as digests age toward the bound the hit-probability
# discount shrinks the credited prefix, so hit rate and tail latency
# degrade gracefully rather than routing on stale claims.
def cluster_prefix_gossip(duration_s: float = 60.0):
    import dataclasses
    import os

    from repro.core.api import ExperimentSpec
    from repro.core.cluster import ClusterConfig
    from repro.core.gossip import GossipConfig
    from repro.core.prefix_cache import PrefixCacheConfig
    from repro.core.router import RouterConfig

    fleets = (8, 16, 32) if duration_s < 60 else (8, 16, 32, 64)
    rps_per_inst = 2.5

    def run_one(size, policy, cross, gossip):
        return ExperimentSpec(
            name=f"cluster_prefix_gossip_{policy}_{size}",
            scenario="shared_prefix", duration_s=duration_s,
            mean_rps=rps_per_inst * size, n_sessions=4 * size, seed=7,
            sim=SimConfig(mode="harli", seed=9),
            cluster=ClusterConfig(
                n_initial=size, autoscale=False, prefill_mode="chained",
                prefix_cache=PrefixCacheConfig(chunks=16,
                                               cross_session=cross),
                gossip=gossip,
                router=RouterConfig(policy=policy))).run()

    arms = (("sync", "cache_aware", True, None),
            ("gossip", "cache_aware_gossip", True, GossipConfig()),
            ("session-keyed", "cache_aware", False, None))
    out = {}
    for size in fleets:
        for arm, policy, cross, gossip in arms:
            t0 = time.time()
            res = run_one(size, policy, cross, gossip)
            out[(arm, size)] = res
            s = res.stats
            tot = max(res.prefix_hits + res.prefix_misses, 1)
            _row(f"cluster_prefix_gossip,{arm},fleet{size}",
                 (time.time() - t0) * 1e6,
                 f"ttft_p99={s.ttft_p99:.3f}|goodput={s.goodput:.2f}"
                 f"|attain={s.slo_attainment:.3f}"
                 f"|hit_rate={res.prefix_hits/tot:.3f}"
                 f"|shared_tokens={res.prefix_shared_hit_tokens}"
                 f"|peeks={res.dispatch_peeks}"
                 f"|digests={res.gossip_published}"
                 f"|digest_bytes={res.gossip_bytes}"
                 f"|stale_discards={res.gossip_stale_discards}")
    big = max(f for f in fleets if f >= 32)
    g, sy = out[("gossip", big)], out[("sync", big)]
    sk = out[("session-keyed", big)]
    _row(f"cluster_prefix_gossip.summary,fleet{big}", 0,
         f"gossip_vs_sync_ttft_p99="
         f"{g.stats.ttft_p99/max(sy.stats.ttft_p99, 1e-9):.2f}x"
         f"|gossip_vs_sessionkeyed_ttft_p99="
         f"{g.stats.ttft_p99/max(sk.stats.ttft_p99, 1e-9):.2f}x"
         f"|goodput_ratio="
         f"{g.stats.goodput/max(sk.stats.goodput, 1e-9):.2f}x"
         f"|sync_peeks={sy.dispatch_peeks}|gossip_peeks={g.dispatch_peeks}"
         f"|win={int(g.dispatch_peeks == 0 and g.stats.ttft_p99 <= 1.1 * sy.stats.ttft_p99 and g.stats.ttft_p99 < sk.stats.ttft_p99 and g.stats.goodput >= 0.99 * sk.stats.goodput)}")

    periods = (0.5, 1.0, 2.0, 4.0, 8.0)
    psize = 16
    pout = {}
    for period in periods:
        t0 = time.time()
        res = run_one(psize, "cache_aware_gossip", True,
                      GossipConfig(period_s=period,
                                   staleness_bound_s=2.0 * period))
        pout[period] = res
        s = res.stats
        tot = max(res.prefix_hits + res.prefix_misses, 1)
        _row(f"cluster_prefix_gossip,period{period:g}",
             (time.time() - t0) * 1e6,
             f"ttft_p99={s.ttft_p99:.3f}|goodput={s.goodput:.2f}"
             f"|hit_rate={res.prefix_hits/tot:.3f}"
             f"|stale_discards={res.gossip_stale_discards}"
             f"|max_used_age={res.gossip_max_used_age:.2f}")

    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        _row("cluster_prefix_gossip.png", 0, "skipped_no_matplotlib")
        return

    C = {"sync": "#2a78d6", "gossip": "#1baf7a",
         "session-keyed": "#eb6834", "ink": "#0b0b0b", "ink2": "#52514e",
         "grid": "#e4e3df", "surface": "#fcfcfb", "slo": "#b3261e"}
    fig, axes = plt.subplots(1, 3, figsize=(10.8, 3.3),
                             facecolor=C["surface"])
    panels = [("TTFT p99 (s)", lambda r: r.stats.ttft_p99),
              ("prefix-cache hit rate", lambda r: r.prefix_hits / max(
                  r.prefix_hits + r.prefix_misses, 1))]
    for ax, (title, get) in zip(axes[:2], panels):
        for arm, _, _, _ in arms:
            ax.plot(fleets, [get(out[(arm, f)]) for f in fleets],
                    marker="o", ms=3.5, lw=1.4, color=C[arm], label=arm)
        ax.set_title(title, fontsize=9.5, color=C["ink"])
        ax.set_xlabel("fleet size (instances)", fontsize=8.5,
                      color=C["ink2"])
        ax.set_xscale("log", base=2)
        ax.set_xticks(fleets)
        ax.set_xticklabels([str(f) for f in fleets])
    ax = axes[2]
    ax.plot(periods, [pout[p].prefix_hits / max(
        pout[p].prefix_hits + pout[p].prefix_misses, 1)
        for p in periods], marker="o", ms=3.5, lw=1.4,
        color=C["gossip"], label="hit rate")
    ax.set_title(f"hit rate vs gossip period (fleet {psize},\n"
                 "staleness bound = 2x period)", fontsize=9.5,
                 color=C["ink"])
    ax.set_xlabel("gossip period (s)", fontsize=8.5, color=C["ink2"])
    ax2 = ax.twinx()
    ax2.plot(periods, [pout[p].stats.ttft_p99 for p in periods],
             marker="s", ms=3.5, lw=1.4, ls="--", color=C["slo"],
             label="TTFT p99 (s)")
    ax2.tick_params(labelsize=8, colors=C["slo"])
    h1, l1 = ax.get_legend_handles_labels()
    h2, l2 = ax2.get_legend_handles_labels()
    ax.legend(h1 + h2, l1 + l2, fontsize=8, frameon=False)
    for a in list(axes):
        a.set_facecolor(C["surface"])
        a.grid(color=C["grid"], lw=0.6)
        a.set_axisbelow(True)
        a.tick_params(labelsize=8, colors=C["ink2"])
        for sp in a.spines.values():
            sp.set_color(C["grid"])
    axes[0].legend(fontsize=8, frameon=False)
    fig.suptitle("Fleet-scale prefix sharing: gossiped digests vs "
                 "synchronous peeks vs session-keyed caching "
                 "(shared_prefix scenario, chained prefill)",
                 fontsize=10.5, color=C["ink"])
    fig.tight_layout()
    out_dir = os.path.join(os.path.dirname(__file__), "figures")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "cluster_prefix_gossip.png")
    fig.savefig(path, dpi=150, facecolor=C["surface"])
    plt.close(fig)
    _row("cluster_prefix_gossip.png", 0, path)


ALL = [fig01_phase_throughput, fig03_trace_batchsize,
       fig04_decode_utilization, fig05_colocation_potential,
       fig08_solo_latency, fig09_quantum_scaling, fig10_colo_latency,
       fig11_throughput_qos, fig12_predictor_error, fig13_memory_timeline,
       fig14_scheduler_timeline, sec87_tp_mode, sec88_overhead,
       cluster_goodput, cluster_fleet_timeline, cluster_prefill_modes,
       cluster_cache_aware, cluster_churn, cluster_survivability,
       cluster_adapter_serving, cluster_prefix_gossip]
